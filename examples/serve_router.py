"""Disaggregated prefill/decode serving + multi-replica routing.

Three parts:
1. Disaggregation: the same mixed-length trace that drives
   ``serve_continuous`` runs through ``serve_disaggregated`` — a
   throughput-oriented prefill tier (pow2 prompt bucketing) hands each
   finished request's KV pages to a fixed-slot decode tier via an
   explicit PageHandoff (a page remap inside the shared pool, no cache
   copy). Tokens are asserted identical to the single-engine paged run;
   with prefix_cache=True the handoff stays refcount-correct across
   trie-shared pages (asserted against the prefix-sharing engine).
2. Routing: a Router partitions the trace over 2 engine replicas with
   load-aware admission (``least_loaded`` replays each candidate
   replica through ``simulate_admission`` and picks the smallest
   projected makespan). Greedy decode makes tokens replica-independent,
   so the routed fleet is asserted token-for-token identical to one big
   engine on the same trace.
3. The trace-driven dryrun: ``simulate_replicas`` replays a Poisson
   arrival trace with per-request deadlines under both routing policies
   and reports fleet-wide TTFT/latency p50/p99 + SLO attainment — the
   numbers ``launch/dryrun.py`` projects for a real decode cell.

With >= 8 host devices (CI sets
XLA_FLAGS=--xla_force_host_platform_device_count=8) parts 1-2 run
sharded on a 2x4 ("data", "model") mesh.

Run:  PYTHONPATH=src python examples/serve_router.py
"""
import jax
import numpy as np
from jax.sharding import Mesh

from repro.models import ModelConfig, init_params
from repro.serve import (
    EngineConfig, Request, Router, make_arrival_trace, serve_continuous,
    serve_disaggregated, simulate_replicas,
)

mesh = None
if len(jax.devices()) >= 8:
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4),
                ("data", "model"))
    print(f"mesh: {dict(mesh.shape)} over {mesh.size} devices")
else:
    print("single device (set XLA_FLAGS=--xla_force_host_platform_"
          "device_count=8 for the sharded path)")

cfg = ModelConfig(name="router-demo", mixer="attn", ffn="swiglu",
                  n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
                  d_ff=128, vocab=256, dtype="float32", remat=False)
params = init_params(jax.random.PRNGKey(0), cfg)

rng = np.random.default_rng(11)
requests = [
    Request(rid=i, tokens=rng.integers(0, cfg.vocab,
                                       size=int(rng.integers(5, 18))),
            max_new_tokens=int(rng.integers(6, 14)), arrival=(i // 3) * 5)
    for i in range(10)
]
econf = EngineConfig(n_slots=4, paged=True, page_size=8)

# -- 1. disaggregated prefill/decode tiers ---------------------------------
single = serve_continuous(params, cfg, requests, econf, mesh=mesh)
dis = serve_disaggregated(params, cfg, requests, econf, mesh=mesh)
assert dis.tokens == single.tokens, \
    "disaggregation must not change a single output token"
print(f"\ndisagg: {dis.stats['handoffs']} handoffs moved "
      f"{dis.stats['handoff_pages']} pages prefill->decode, "
      f"{dis.stats['prefill_tokens']} prefill tokens, "
      f"{dis.stats['generated_tokens']} generated "
      f"(sharded={dis.stats['sharded']}) — tokens == single engine")

# shared system prompt: handoffs remap trie-shared pages refcount-safely
sys_p = rng.integers(0, cfg.vocab, size=17)
shared_reqs = [
    Request(rid=50 + i,
            tokens=np.concatenate(
                [sys_p, rng.integers(0, cfg.vocab,
                                     size=int(rng.integers(2, 6)))]),
            max_new_tokens=int(rng.integers(5, 10)), arrival=(i // 2) * 3)
    for i in range(6)
]
pconf = econf.replace(prefix_cache=True)
sh_single = serve_continuous(params, cfg, shared_reqs, pconf, mesh=mesh)
sh_dis = serve_disaggregated(params, cfg, shared_reqs, pconf, mesh=mesh)
assert sh_dis.tokens == sh_single.tokens
assert sh_dis.stats["prefix_hits"] > 0
print(f"prefix-shared disagg: {sh_dis.stats['prefix_hits']} trie hits, "
      f"{sh_dis.stats['prefill_tokens']} prefill tokens "
      f"(vs {dis.stats['prefill_tokens']} unshared trace) — parity held")

# -- 2. routed fleet: 2 replicas, load-aware admission ---------------------
router = Router(2, econf, policy="least_loaded", engine="disagg")
routed = router.serve(params, cfg, requests, mesh=mesh)
assert routed.tokens == single.tokens, \
    "routing must not change any request's tokens"
print(f"\nrouter: {routed.stats['replicas']} replicas took "
      f"{routed.stats['replica_requests']} requests "
      f"(policy={routed.stats['policy']}, engine={routed.stats['engine']})"
      f" — fleet tokens == single engine")

# -- 3. trace-driven SLO dryrun across routing policies --------------------
trace = make_arrival_trace(np.random.default_rng(3), 24, vocab=cfg.vocab,
                           mean_gap_steps=0.5, deadline_slack=4.0,
                           step_time_us=1.0)
print(f"\n{len(trace)} Poisson arrivals, per-request deadlines "
      f"(slack 4x ideal service time), 2 replicas x 4 slots:")
for pol in ("round_robin", "least_loaded"):
    s = simulate_replicas(trace, 2, policy=pol, n_slots=4,
                          step_time_us=1.0)
    print(f"  {pol:<12} ttft p50/p99 = {s['ttft_us']['p50']:.0f}/"
          f"{s['ttft_us']['p99']:.0f} us, latency p50/p99 = "
          f"{s['latency_us']['p50']:.0f}/{s['latency_us']['p99']:.0f} us, "
          f"SLO attainment {s['slo_attainment']:.0%}")
print("done")
