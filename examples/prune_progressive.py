"""Paper Algorithm 1 end-to-end: auto lossless CSB pruning.

Trains a small GRU classifier on the synthetic sentiment task, then runs
the progressive ADMM-CSB flow to find the maximum lossless pruning rate.

Run:  PYTHONPATH=src python examples/prune_progressive.py [--fast]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax

from repro.core import CSBSpec, ProgressivePruner, density
from benchmarks.common import train_rnn_classifier

FAST = "--fast" in sys.argv

print("=== baseline (dense) training ===")
cell, dense_params, acc_fn = train_rnn_classifier(
    "gru", steps=40 if FAST else 80, seed=0)
baseline = acc_fn()
lossless = baseline - 0.02
print(f"dense accuracy: {baseline:.3f}  (lossless bar: {lossless:.3f})\n")

ctl = ProgressivePruner(init_pr=0.25, init_step=0.25)
history = []
while not ctl.done and len(history) < (3 if FAST else 8):
    rate = ctl.prune_rate
    spec = CSBSpec(bm=8, bn=8, prune_rate=rate)
    specs = jax.tree.map(lambda _: None, dense_params)
    for k, w in dense_params.items():
        if hasattr(w, "ndim") and w.ndim == 2 and k not in ("emb", "out"):
            specs[k] = spec
    _, pruned, acc2 = train_rnn_classifier(
        "gru", specs=specs, steps=30 if FAST else 60, seed=0)
    acc = acc2()
    ok = acc >= lossless
    history.append((rate, acc, ok))
    print(f"rate {rate:.3f} ({1/(1-rate):.1f}x): acc {acc:.3f} "
          f"{'LOSSLESS' if ok else 'over-pruned'}")
    ctl.update(ok)

print(f"\nbest lossless rate: {ctl.best_lossless_rate:.3f} "
      f"=> {ctl.best_compression:.1f}x compression")
