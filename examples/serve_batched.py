"""End-to-end serving driver (the paper's workload shape: inference).

Two parts:
1. Batched LM serving: prefill a batch of prompts on a small decoder and
   greedily decode new tokens through the jitted single-token step.
2. Faster-than-realtime RNN frame serving: an LSTM with CSB-compressed
   weights processes a stream of frames; reports us/frame against the
   paper's 500 us realtime bar (CPU-interpret numbers are illustrative —
   the bar is meaningful on real hardware).

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cells import init_params as cell_init, make_cell
from repro.core import CSBSpec, csb_masks, csb_project, padded_csb_from_dense
from repro.models import ModelConfig, init_params
from repro.serve import ServeConfig, generate, rnn_serve_frames

# -- 1. batched LM serving ------------------------------------------------
cfg = ModelConfig(name="serve-demo", mixer="attn", ffn="swiglu",
                  n_layers=4, d_model=128, n_heads=4, n_kv=2, head_dim=32,
                  d_ff=256, vocab=512, dtype="float32", remat=False)
params = init_params(jax.random.PRNGKey(0), cfg)
prompts = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab)

t0 = time.perf_counter()
out = generate(params, cfg, prompts, ServeConfig(max_new_tokens=16))
dt = time.perf_counter() - t0
new_tokens = 8 * 16
print(f"batched serve: {out.shape[0]} seqs x {out.shape[1]} tokens "
      f"({new_tokens} new) in {dt:.2f}s "
      f"-> {dt / new_tokens * 1e3:.1f} ms/token (CPU)")

# -- 2. CSB-RNN frame serving ----------------------------------------------
cell = make_cell("lstm", 64, 128)
wparams = cell_init(cell, jax.random.PRNGKey(2))
spec = CSBSpec(bm=16, bn=16, prune_rate=0.9)     # 10x compression
csb_params = {}
for k, w in wparams.items():
    if w.ndim == 2:
        z = csb_project(w, spec)
        rm, cm = csb_masks(w, spec)
        csb_params[k] = padded_csb_from_dense(
            np.asarray(z), 16, 16, row_mask=np.asarray(rm),
            col_mask=np.asarray(cm))
    else:
        csb_params[k] = w

frames = jax.random.normal(jax.random.PRNGKey(3), (32, 4, 64))
outs, _, us = rnn_serve_frames(cell, csb_params, frames)
print(f"CSB-RNN frames: {frames.shape[0]} frames x batch {frames.shape[1]} "
      f"-> {us:.1f} us/frame (interpret mode; realtime bar: 500 us)")
print("done")
