"""End-to-end serving driver (the paper's workload shape: inference).

Seven parts:
1. Continuous batching: mixed-length prompts arriving over time flow
   through a fixed set of decode slots — finished requests are evicted
   and the next queued prompt prefilled into the freed slot mid-decode.
   With >= 8 host devices (CI sets
   XLA_FLAGS=--xla_force_host_platform_device_count=8) the whole loop
   runs sharded on a 2x4 ("data", "model") mesh: params placed by
   param_specs/csb_shard_specs, cache + token batch data-parallel via
   cache_specs/batch_specs. The run goes through the PAGED cache
   (paged=True): fixed-size token pages from a shared pool, pow2
   prompt-bucketed prefill — and its tokens are asserted identical to
   the contiguous engine's.
2. The paging win: the same token budget is handed to both engines as
   a hard cap. The contiguous engine can only carve it into 2
   worst-case-length slots and queues the rest; the paged pool
   reserves per-request pages and runs more of the mixed-length trace
   concurrently — asserted, not just printed.
3. Prefix sharing: every request opens with the same system prompt, so
   with prefix_cache=True the first admission registers the prompt
   pages in the radix trie and every later admission maps them
   directly — zero prefill compute for the shared span, copy-on-write
   at the divergence page. Hit count, prefill-token reduction and
   token-for-token parity with the non-shared engine are asserted.
3b. Speculative decoding: the part-1 trace re-runs with
   speculative=True — a CSB-pruned copy of the target (the paper's own
   compression scheme as the draft model) proposes spec_k tokens per
   round and the target verifies them in one multi-position paged
   decode step. Greedy trace, so token-for-token parity with part 1 is
   asserted; acceptance counters are printed.
4. Fixed-batch LM serving: prefill a batch of prompts and greedily
   decode through the jitted single-token step.
5. Faster-than-realtime RNN frame serving: an LSTM with CSB-compressed
   weights processes a stream of frames — on the mesh the CSB block
   grid is cycle-balanced over the "model" axis and executed by the
   shard_map kernel; reports us/frame against the paper's 500 us
   realtime bar (CPU-interpret numbers are illustrative — the bar is
   meaningful on real hardware).
6. Request-lifecycle tracing: the part-1 trace re-runs with
   ``repro.obs`` enabled, exports a Chrome-trace JSON
   (``serve_trace.json``, loadable in https://ui.perfetto.dev — CI
   uploads it as an artifact) and prints the latency breakdown
   (per-span percentiles + the queue-wait -> prefill -> TTFT -> decode
   request table). See docs/observability.md.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.cells import init_params as cell_init, make_cell
from repro.core import CSBSpec, csb_masks, csb_project, padded_csb_from_dense
from repro.models import ModelConfig, init_params
from repro.serve import (
    EngineConfig, Request, generate, rnn_serve_frames, serve_continuous,
)

mesh = None
if len(jax.devices()) >= 8:
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4),
                ("data", "model"))
    print(f"mesh: {dict(mesh.shape)} over {mesh.size} devices")
else:
    print("single device (set XLA_FLAGS=--xla_force_host_platform_"
          "device_count=8 for the sharded path)")

cfg = ModelConfig(name="serve-demo", mixer="attn", ffn="swiglu",
                  n_layers=4, d_model=128, n_heads=4, n_kv=2, head_dim=32,
                  d_ff=256, vocab=512, dtype="float32", remat=False)
params = init_params(jax.random.PRNGKey(0), cfg)

# -- 1. continuous batching: mixed lengths, arriving over time -------------
rng = np.random.default_rng(7)
requests = [
    Request(rid=i, tokens=rng.integers(0, cfg.vocab,
                                       size=int(rng.integers(6, 20))),
            max_new_tokens=int(rng.integers(8, 17)), arrival=(i // 3) * 6)
    for i in range(9)
]
print(f"\n{len(requests)} requests, prompt lens "
      f"{[r.prompt_len for r in requests]}, arrivals "
      f"{[r.arrival for r in requests]}, 4 slots, PAGED cache")
paged_cfg = EngineConfig(n_slots=4, paged=True, page_size=8)
res = serve_continuous(params, cfg, requests, paged_cfg, mesh=mesh)
st = res.stats
pg = st["paging"]
print(f"paged serve: {st['requests']} requests, "
      f"{st['generated_tokens']} tokens in {res.wall_s:.2f}s "
      f"({st['tokens_per_sec']:.1f} tok/s, occupancy "
      f"{st['occupancy']:.0%}, {st['prefills']} prefills over "
      f"{st['decode_steps']} decode steps, sharded={st['sharded']}, "
      f"bucketed_prefill={st['bucketed_prefill']})")
print(f"  pages: peak {pg['peak_pages']}/{pg['n_pages']} x "
      f"{pg['page_size']} tokens, internal fragmentation "
      f"{pg['internal_fragmentation']:.1%}")
res_contig = serve_continuous(params, cfg, requests,
                              EngineConfig(n_slots=4), mesh=mesh)
assert res.tokens == res_contig.tokens, \
    "paged and contiguous engines must emit identical tokens"
print("  paged tokens == contiguous tokens: verified")

# -- 2. the paging win: same token budget, more concurrency ----------------
long_req = Request(rid=100, tokens=rng.integers(0, cfg.vocab, size=16),
                   max_new_tokens=32)                       # total 48
shorts = [Request(rid=101 + i,
                  tokens=rng.integers(0, cfg.vocab, size=8),
                  max_new_tokens=8) for i in range(4)]      # total 16
cache_len = 48
budget = 2 * cache_len                                      # 96 tokens
paged = serve_continuous(
    params, cfg, [long_req] + shorts,
    EngineConfig(n_slots=4, paged=True, page_size=8, cache_len=cache_len,
                 pool_pages=budget // 8), mesh=mesh)
contig = serve_continuous(
    params, cfg, [long_req] + shorts,
    EngineConfig(n_slots=budget // cache_len, cache_len=cache_len),
    mesh=mesh)
assert paged.tokens == contig.tokens
assert paged.stats["peak_active"] > contig.stats["peak_active"]
print(f"\nsame {budget}-token budget: contiguous fits "
      f"{contig.stats['peak_active']} concurrent requests "
      f"({contig.stats['decode_steps']} decode steps), paged fits "
      f"{paged.stats['peak_active']} ({paged.stats['decode_steps']} "
      f"steps) — identical outputs")

# -- 3. prefix sharing: a common system prompt across every request --------
sys_prompt = rng.integers(0, cfg.vocab, size=21)    # 2 whole pages + 5
shared_reqs = [
    Request(rid=200 + i,
            tokens=np.concatenate(
                [sys_prompt,
                 rng.integers(0, cfg.vocab, size=int(rng.integers(2, 7)))]),
            max_new_tokens=int(rng.integers(6, 13)), arrival=(i // 3) * 4)
    for i in range(9)
]
base = serve_continuous(params, cfg, shared_reqs, paged_cfg, mesh=mesh)
shared = serve_continuous(
    params, cfg, shared_reqs,
    paged_cfg.replace(prefix_cache=True), mesh=mesh)
assert shared.tokens == base.tokens, \
    "prefix sharing must not change a single output token"
# every request past the first matches the system prompt in the trie
assert shared.stats["prefix_hits"] == len(shared_reqs) - 1, shared.stats
assert shared.stats["prefill_tokens"] < base.stats["prefill_tokens"]
saved = base.stats["prefill_tokens"] - shared.stats["prefill_tokens"]
print(f"\nshared {len(sys_prompt)}-token system prompt x "
      f"{len(shared_reqs)} requests, prefix_cache=True: "
      f"{shared.stats['prefix_hits']} trie hits, "
      f"{shared.stats['shared_pages']} pages mapped shared, "
      f"{shared.stats['paging']['cow_copies']} CoW copies")
print(f"  prefill compute: {base.stats['prefill_tokens']} tokens without "
      f"sharing -> {shared.stats['prefill_tokens']} with "
      f"({saved} saved) — identical outputs")

# -- 3b. speculative decoding: CSB-pruned self-draft + k-token verify ------
spec_cfg = paged_cfg.replace(speculative=True, spec_k=4,
                             draft_prune_rate=0.5)
spec = serve_continuous(params, cfg, requests, spec_cfg, mesh=mesh)
assert spec.tokens == res.tokens, \
    "speculative decoding must not change a single output token at T=0"
sp = spec.stats["speculative"]
print(f"\nspeculative decode (k={sp['spec_k']}, draft = target CSB-pruned "
      f"at {sp['draft_prune_rate']:.0%}): {sp['rounds']} verify rounds, "
      f"{sp['proposed']} drafted, {sp['accepted']} accepted "
      f"(acceptance {sp['acceptance_rate']:.0%}), "
      f"{spec.stats['generated_tokens'] / max(sp['rounds'], 1):.2f} "
      f"tokens per target step — identical outputs")

# -- 4. fixed-batch LM serving ---------------------------------------------
prompts = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab)
t0 = time.perf_counter()
out = generate(params, cfg, prompts, EngineConfig(max_new_tokens=16),
               mesh=mesh)
jax.block_until_ready(out)
dt = time.perf_counter() - t0
new_tokens = 8 * 16
print(f"\nbatched generate: {out.shape[0]} seqs x {out.shape[1]} tokens "
      f"({new_tokens} new) in {dt:.2f}s "
      f"-> {dt / new_tokens * 1e3:.1f} ms/token (CPU)")

# -- 5. CSB-RNN frame serving ----------------------------------------------
cell = make_cell("lstm", 64, 128)
wparams = cell_init(cell, jax.random.PRNGKey(2))
spec = CSBSpec(bm=16, bn=16, prune_rate=0.9)     # 10x compression
csb_params = {}
for k, w in wparams.items():
    if w.ndim == 2:
        z = csb_project(w, spec)
        rm, cm = csb_masks(w, spec)
        csb_params[k] = padded_csb_from_dense(
            np.asarray(z), 16, 16, row_mask=np.asarray(rm),
            col_mask=np.asarray(cm))
    else:
        csb_params[k] = w

frames = jax.random.normal(jax.random.PRNGKey(3), (32, 4, 64))
outs, _, us = rnn_serve_frames(cell, csb_params, frames, mesh=mesh)
where = "sharded mesh" if mesh is not None else "single device"
print(f"\nCSB-RNN frames ({where}): {frames.shape[0]} frames x batch "
      f"{frames.shape[1]} -> {us:.1f} us/frame "
      f"(interpret mode; realtime bar: 500 us)")

# -- 6. request-lifecycle tracing ------------------------------------------
from repro import obs
from repro.obs import trace as obs_trace
from repro.obs.summary import report

obs.enable_all()
traced = serve_continuous(params, cfg, requests, paged_cfg, mesh=mesh)
assert traced.tokens == res.tokens          # tracing changes nothing
trace_path = obs_trace.export_chrome("serve_trace.json")
obs.disable_all()
st = traced.stats
print(f"\ntraced re-run: compile {st['compile_time_s']:.2f}s (warm), "
      f"steady {st['steady_tokens_per_sec']:.1f} tok/s "
      f"(blended {st['tokens_per_sec']:.1f})")
print(report(trace_path))
print(f"\nopen {trace_path} in https://ui.perfetto.dev to see the "
      f"engine + per-request tracks")
print("done")
