"""End-to-end training driver: char-LM with ADMM-CSB pruning, periodic
checkpointing + auto-resume, straggler telemetry.

Default config is CPU-feasible (~2M params, 200 steps). ``--big`` selects
a ~100M-param decoder (the deliverable shape — run it on real hardware;
a few steps/minute on this 1-core container).

Run:  PYTHONPATH=src python examples/train_lm_e2e.py [--big] [--steps N]
      [--prune] [--ckpt DIR]
"""
import argparse

import jax

from repro.core import CSBSpec
from repro.data import CharLMTask, lm_batch_iterator
from repro.models import ModelConfig, forward_loss, init_params
from repro.optim import linear_warmup_cosine
from repro.train import TrainConfig, train

ap = argparse.ArgumentParser()
ap.add_argument("--big", action="store_true", help="~100M params")
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--prune", action="store_true", help="ADMM-CSB on FFN")
ap.add_argument("--ckpt", default=None)
ap.add_argument("--batch", type=int, default=16)
ap.add_argument("--seq", type=int, default=64)
args = ap.parse_args()

if args.big:
    cfg = ModelConfig(name="charlm-100m", mixer="attn", ffn="swiglu",
                      n_layers=12, d_model=768, n_heads=12, n_kv=4,
                      head_dim=64, d_ff=3072, vocab=256, dtype="float32")
else:
    cfg = ModelConfig(name="charlm-2m", mixer="attn", ffn="swiglu",
                      n_layers=4, d_model=128, n_heads=4, n_kv=2,
                      head_dim=32, d_ff=512, vocab=64, dtype="float32",
                      remat=False)

print(f"model: {cfg.name}, {cfg.param_count():,} params")
task = CharLMTask(vocab=cfg.vocab, seed=0)
params = init_params(jax.random.PRNGKey(0), cfg)

specs = None
if args.prune:
    specs = jax.tree.map(lambda _: None, params)
    specs["layers"]["ffn"]["w_gate"] = CSBSpec(bm=32, bn=32, prune_rate=0.75)
    specs["layers"]["ffn"]["w_up"] = CSBSpec(bm=32, bn=32, prune_rate=0.75)
    specs["layers"]["ffn"]["w_down"] = CSBSpec(bm=32, bn=32, prune_rate=0.75)
    print("ADMM-CSB pruning enabled on FFN weights (4x)")

tcfg = TrainConfig(
    lr=3e-3 if not args.big else 6e-4,
    steps=args.steps,
    log_every=10,
    ckpt_dir=args.ckpt,
    ckpt_every=50,
    admm_every=25 if args.prune else 0,
    optimizer="adamw",
)
sched = linear_warmup_cosine(tcfg.lr, warmup=20, steps=args.steps)
params, history = train(
    lambda p, b: forward_loss(p, b, cfg),
    params,
    lm_batch_iterator(task, args.batch, args.seq),
    tcfg,
    lr_schedule=sched,
    csb_specs=specs,
)
first = sum(h["loss"] for h in history[:10]) / max(len(history[:10]), 1)
last = sum(h["loss"] for h in history[-10:]) / max(len(history[-10:]), 1)
print(f"\nloss: {first:.3f} -> {last:.3f} over {len(history)} steps")
if args.prune:
    from repro.core import density
    d = float(density(params["layers"]["ffn"]["w_gate"]))
    print(f"final FFN w_gate density: {d:.3f} (target 0.25)")
