"""Quickstart: the CSB-RNN pipeline in ~60 lines.

1. Take an LSTM layer's weight matrices.
2. CSB-prune them (projection only, no retraining here).
3. Encode into the CSB sparse format; inspect compression + NIO.
4. Run the Pallas CSB-MVM kernel and check it against the oracle.
5. Compile the workload-balanced schedule and simulate utilization.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.cells import init_params, make_cell
from repro.core import (
    CSBMatrix, CSBSpec, csb_masks, csb_project, padded_csb_from_dense,
)
from repro.engine import EngineConfig, simulate_matrix
from repro.kernels.ops import csb_matvec
from repro.kernels.ref import csb_mvm_ref

cell = make_cell("lstm", 128, 256)
params = init_params(cell, jax.random.PRNGKey(0))
spec = CSBSpec(bm=32, bn=32, prune_rate=0.875)   # 8x compression target

print(f"LSTM 128->256, {cell.param_count():,} params")
print(f"CSB spec: {spec.bm}x{spec.bn} blocks, "
      f"{spec.compression_ratio:.1f}x target\n")

total_nnz = total = 0
for name in ("W_i", "U_i"):                      # input + recurrent of gate i
    w = params[name]
    z = csb_project(w, spec)
    rm, cm = csb_masks(w, spec)
    csb = CSBMatrix.from_dense(np.asarray(z), 32, 32,
                               np.asarray(rm), np.asarray(cm))
    total_nnz += csb.nnz
    total += w.size
    print(f"{name}: {w.shape} -> {csb.nnz:,} nnz "
          f"({csb.compression_ratio():.1f}x), NIO={csb.nio():.2f} "
          f"(CSR would be {CSBMatrix.csr_nio(csb.nnz, w.shape[0]):.2f})")

    # kernel vs oracle
    p = padded_csb_from_dense(np.asarray(z), 32, 32,
                              row_mask=np.asarray(rm), col_mask=np.asarray(cm))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, w.shape[1]))
    y_kernel = csb_matvec(p, x)       # Pallas (interpret mode on CPU)
    y_oracle = csb_mvm_ref(p, x)
    err = float(jnp.max(jnp.abs(y_kernel - y_oracle)))
    print(f"      kernel vs oracle max err: {err:.2e}")

    # engine utilization with and without workload sharing
    e = EngineConfig(K=4, L=4, P=4, Q=4)
    eff0 = simulate_matrix(csb, e, "none").efficiency
    eff2 = simulate_matrix(csb, e, "2d").efficiency
    lat = simulate_matrix(csb, e, "2d").latency_us
    print(f"      engine: {eff0:.0%} util no-sharing -> {eff2:.0%} "
          f"with 2D sharing; {lat:.2f} us/MVM @200MHz\n")

print(f"overall compression: {total / total_nnz:.1f}x")
