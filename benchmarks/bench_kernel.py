"""CSB-MVM Pallas kernel accounting (replaces paper Fig. 11's FPGA
resource table with the TPU-relevant quantities): VMEM working set per
BlockSpec tile, padded-vs-true FLOPs across block sizes / pruning rates,
and interpret-mode allclose latency vs the jnp oracle.

Also benches the Pallas paged-attention decode kernel
(``kernel/paged_attn/decode``, GATED — see benchmarks/diff.py) against
the XLA ``paged_gather`` fallback it replaces (informational oracle
row, allclose-checked).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CSBSpec, csb_masks, csb_project, padded_csb_from_dense
from repro.kernels import paged_attn_decode
from repro.kernels.ops import csb_matvec
from repro.kernels.ref import csb_mvm_ref
from repro.models.layers import paged_gather
from .common import emit, synthetic_rnn_weight, timed


def vmem_bytes(p, batch_tile: int, group: int) -> int:
    """Working set one grid step stages into VMEM."""
    bm, bn = p.block
    pm, pn = p.pm, p.pn
    x_tile = batch_tile * group * bn * 4
    w_tile = group * (pm * pn * p.vals.dtype.itemsize + pm * 4 + pn * 4 + 8)
    o_tile = batch_tile * bm * 4
    return x_tile + w_tile + o_tile


def _paged_attn_rows() -> None:
    """Paged decode attention: the kernel walks the page table in-VMEM;
    the fallback materializes a (B, max_pages*P) HBM gather per step."""
    b, h, kv, d, psz, mp = 8, 8, 4, 64, 16, 8
    n_pages = b * mp
    scale = 1.0 / d ** 0.5
    ks = jax.random.split(jax.random.PRNGKey(31), 3)
    k_pool = jax.random.normal(ks[0], (n_pages + 1, psz, kv, d))
    v_pool = jax.random.normal(ks[1], (n_pages + 1, psz, kv, d))
    q = jax.random.normal(ks[2], (b, h, d))
    table = jnp.arange(n_pages, dtype=jnp.int32).reshape(b, mp)
    pos = jnp.full((b,), mp * psz - 2, jnp.int32)

    @jax.jit
    def gather_ref(q, kp, vp, tab, pos):
        kg = paged_gather(kp, tab)                  # (B, T, KV, D)
        vg = paged_gather(vp, tab)
        rep = h // kv
        qh = q.reshape(b, kv, rep, d)
        sc = jnp.einsum("bgrd,bkgd->bgrk", qh, kg,
                        preferred_element_type=jnp.float32)
        mask = jnp.arange(kg.shape[1])[None, :] <= pos[:, None]
        sc = jnp.where(mask[:, None, None, :], sc * scale, -1e30)
        p = jax.nn.softmax(sc, axis=-1)
        o = jnp.einsum("bgrk,bkgd->bgrd", p, vg,
                       preferred_element_type=jnp.float32)
        return o.reshape(b, h, d)

    ker = jax.jit(lambda *a: paged_attn_decode(*a, scale=scale))
    y_ref, t_ref = timed(lambda: gather_ref(q, k_pool, v_pool, table, pos),
                         iters=5, reduce="min")
    y_ker, t_ker = timed(lambda: ker(q, k_pool, v_pool, table, pos),
                         iters=5, reduce="min")
    err = float(jnp.max(jnp.abs(y_ker - y_ref)))
    # the /decode row joins the diff.py gate family (with the /mvm rows)
    emit("kernel/paged_attn/decode", t_ker,
         f"T={mp * psz};slots={b};allclose_err={err:.2e}")
    emit("kernel/paged_attn/gather_oracle", t_ref,
         f"gathered_mb={(2 * b * mp * psz * kv * d * 4) / 2**20:.2f}")
    assert err < 1e-3


def run() -> None:
    _paged_attn_rows()
    key = jax.random.PRNGKey(23)
    w = synthetic_rnn_weight(key, (1024, 1024))
    x = jax.random.normal(key, (8, 1024))
    for bm in (32, 64, 128):
        for rate in (0.75, 0.9):
            spec = CSBSpec(bm=bm, bn=bm, prune_rate=rate)
            z = csb_project(w, spec)
            rm, cm = csb_masks(w, spec)
            p = padded_csb_from_dense(
                np.asarray(z), bm, bm, pad_to=8,
                row_mask=np.asarray(rm), col_mask=np.asarray(cm))
            pad_ratio = p.padded_flops_per_mvm() / max(
                p.true_flops_per_mvm(), 1)
            vb = vmem_bytes(p, batch_tile=8, group=1)
            y_ref, t_ref = timed(lambda: csb_mvm_ref(p, x))
            y_ker, t_ker = timed(lambda: csb_matvec(p, x), iters=5,
                                 reduce="min")
            err = float(jnp.max(jnp.abs(y_ker - y_ref)))
            tag = f"kernel/b{bm}/r{int(rate*100)}"
            # /mvm is the row benchmarks/diff.py gates on (kernel latency
            # proper); the oracle/static rows are informational
            emit(f"{tag}/mvm", t_ker, f"pad_flop_ratio={pad_ratio:.3f}")
            emit(f"{tag}/pad_flop_ratio", 0.0, f"{pad_ratio:.3f}")
            emit(f"{tag}/vmem_kb", 0.0, f"{vb/1024:.1f}")
            emit(f"{tag}/oracle", t_ref, f"allclose_err={err:.2e}")
            assert err < 1e-3


if __name__ == "__main__":
    run()
