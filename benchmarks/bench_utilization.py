"""Paper Fig. 12: CSB-Engine utilization under workload sharing.

4x4 PEGroups x 4x4 PEs (the paper's measurement config), CSB-pruned
matrices with paper-benchmark dims, block sizes {16, 32, 64}, sharing
modes none / 1D / 2D. Expected ladder: ~42% -> ~72% -> ~94%.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import CSBSpec, csb_project
from repro.engine.simulator import EngineConfig, simulate_matrix
from .common import csb_encode_weight, emit, synthetic_rnn_weight


LAYER_DIMS = {
    "MT1-L2": (1024, 256),    # 4x(256x256) stacked gates
    "SR2-L8": (3072, 1024),
    "SC1-L15": (2048, 512),
}


def run() -> None:
    e = EngineConfig(K=4, L=4, P=4, Q=4)
    key = jax.random.PRNGKey(7)
    agg = {m: [] for m in ("none", "horizontal", "2d")}
    for lname, dims in LAYER_DIMS.items():
        key, sub = jax.random.split(key)
        w = synthetic_rnn_weight(sub, dims, imbalance=2.0)
        for bm in (16, 32, 64):
            spec = CSBSpec(bm=bm, bn=bm, prune_rate=0.85)
            csb = csb_encode_weight(csb_project(w, spec), spec)
            for mode in ("none", "horizontal", "2d"):
                t0 = time.perf_counter()
                r = simulate_matrix(csb, e, mode)
                dt = (time.perf_counter() - t0) * 1e6
                agg[mode].append(r.efficiency)
                emit(f"fig12/{lname}/b{bm}/{mode}", dt,
                     f"eff={r.efficiency:.3f}")
    for mode, vals in agg.items():
        emit(f"fig12/avg/{mode}", 0.0, f"eff={np.mean(vals):.3f}")


if __name__ == "__main__":
    run()
