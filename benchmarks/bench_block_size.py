"""Paper Fig. 10: block-size trade-off — attainable pruning rate vs
normalized index overhead (NIO).

(a) On a task-trained RNN, search the max lossless rate per block size.
(b) On RNN-statistics weight matrices (paper-dim), the NIO per block
    size at a fixed rate, vs the CSR overhead of non-structured pruning.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import CSBMatrix, CSBSpec, csb_project, magnitude_project
from .common import csb_encode_weight, emit, synthetic_rnn_weight, \
    train_rnn_classifier


def run() -> None:
    # -- (a) lossless rate per block size (small trained model) ----------
    for bm in (8, 16):
        t0 = time.perf_counter()
        _, dense_params, acc_fn = train_rnn_classifier("gru", seed=1)
        target = acc_fn() - 0.05
        best = 0.0
        for rate in (0.5, 0.75, 0.875):
            specs = jax.tree.map(lambda _: None, dense_params)
            for k, w in dense_params.items():
                if hasattr(w, "ndim") and w.ndim == 2 \
                        and k not in ("emb", "out"):
                    specs[k] = CSBSpec(bm=bm, bn=bm, prune_rate=rate)
            _, _, acc2 = train_rnn_classifier("gru", specs=specs, seed=1,
                                              steps=120)
            if acc2() >= target:
                best = rate
        dt = (time.perf_counter() - t0) * 1e6
        emit(f"fig10a/block{bm}/lossless_rate", dt,
             f"{1/(1-best):.2f}x" if best else "none")

    # -- (b) NIO vs block size on paper-dim matrices ----------------------
    key = jax.random.PRNGKey(0)
    w = synthetic_rnn_weight(key, (1024, 1024))
    rate = 0.9
    nnz_ns = int((np.asarray(magnitude_project(w, rate)) != 0).sum())
    emit("fig10b/nonstructured/csr_nio", 0.0,
         f"{CSBMatrix.csr_nio(nnz_ns, 1024):.3f}")
    for bm in (16, 32, 64, 128):
        t0 = time.perf_counter()
        spec = CSBSpec(bm=bm, bn=bm, prune_rate=rate)
        csb = csb_encode_weight(csb_project(w, spec), spec)
        dt = (time.perf_counter() - t0) * 1e6
        emit(f"fig10b/block{bm}/nio", dt, f"{csb.nio():.3f}")
        emit(f"fig10b/block{bm}/achieved_cr", 0.0,
             f"{csb.compression_ratio():.2f}x")


if __name__ == "__main__":
    run()
