# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
# Each benchmark runs in its OWN subprocess: XLA:CPU's JIT accumulates
# dylib/symbol state over hundreds of compilations and eventually fails
# with "Failed to materialize symbols" in a long-lived process; process
# isolation keeps every table reproducible.
import os
import subprocess
import sys
import time

BENCHES = [
    ("table1", "bench_pruning_rate"),
    ("fig10", "bench_block_size"),
    ("table2", "bench_compare_schemes"),
    ("fig12", "bench_utilization"),
    ("table3", "bench_latency"),
    ("kernel", "bench_kernel"),
    ("roofline", "bench_roofline"),
]


def _run_inprocess(mod_name: str) -> None:
    import importlib

    mod = importlib.import_module(f"benchmarks.{mod_name}")
    mod.run()


def main() -> None:
    args = sys.argv[1:]
    if len(args) == 2 and args[0] == "--worker":
        _run_inprocess(args[1])
        return

    only = args[0] if args else None
    print("name,us_per_call,derived")
    failures = 0
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), root,
                    env.get("PYTHONPATH", "")) if p)
    for name, mod in BENCHES:
        if only and only != name:
            continue
        t0 = time.perf_counter()
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--worker", mod],
            env=env, cwd=root, capture_output=True, text=True)
        for line in proc.stdout.splitlines():
            if line.count(",") >= 2 and not line.startswith("name,"):
                print(line, flush=True)
        if proc.returncode != 0:
            failures += 1
            err = proc.stderr.strip().splitlines()
            print(f"{name}/ERROR,0,{err[-1][:160] if err else 'unknown'}",
                  flush=True)
        print(f"{name}/total,{(time.perf_counter()-t0)*1e6:.0f},done",
              flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
