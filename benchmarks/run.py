# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
# Each benchmark runs in its OWN subprocess: XLA:CPU's JIT accumulates
# dylib/symbol state over hundreds of compilations and eventually fails
# with "Failed to materialize symbols" in a long-lived process; process
# isolation keeps every table reproducible.
#
# EVERY table additionally lands a machine-readable perf record at
# benchmarks/results/BENCH_<name>.json so the perf trajectory is tracked
# across PRs, not just printed. Records carry a machine-calibration
# measurement (a fixed numpy matmul, timed in the same worker) so
# benchmarks/diff.py can separate "this runner is slower" from "this
# kernel regressed" when diffing against the committed baseline.
import json
import os
import subprocess
import sys
import time

BENCHES = [
    ("table1", "bench_pruning_rate"),
    ("fig10", "bench_block_size"),
    ("table2", "bench_compare_schemes"),
    ("fig12", "bench_utilization"),
    ("table3", "bench_latency"),
    ("kernel", "bench_kernel"),
    ("roofline", "bench_roofline"),
    ("serve", "bench_serve"),
]


def _calibration_us(iters: int = 9) -> float:
    """Fixed-size numpy matmul latency — a jax-free proxy for this
    machine's speed, stored in every record for cross-machine diffs.
    Median of several runs after warmup: single-shot timings on shared
    runners spread several-x (thread ramp-up, throttling windows), and
    diff.py's normalization is only as good as this number."""
    import numpy as np

    a = np.ones((768, 768), np.float32)
    b = np.ones((768, 768), np.float32)
    a @ b
    a @ b  # warm the BLAS path / thread pool
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        a @ b
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


def _run_inprocess(mod_name: str) -> None:
    import importlib

    import jax

    # metadata rows for the coordinator's perf record — they describe
    # THIS worker (the coordinator stays jax-free by design, see header)
    print(f"_meta/backend,0,{jax.default_backend()}"
          f"/{jax.devices()[0].device_kind}", flush=True)
    print(f"_meta/calib,{_calibration_us():.3f},np_matmul768", flush=True)
    mod = importlib.import_module(f"benchmarks.{mod_name}")
    mod.run()


def _parse_row(line: str) -> dict | None:
    """name,us,derived -> record row. ``derived`` round-trips as float
    when numeric (pruning rate, utilization, ...) and as string
    otherwise — no table-specific schema."""
    rname, us, derived = line.split(",", 2)
    try:
        us_f = float(us)
    except ValueError:
        return None
    try:
        dval: float | str = float(derived)
    except ValueError:
        dval = derived
    return {"name": rname, "us_per_call": us_f, "derived": dval}


def _perf_record(name: str, rows: list[dict], meta: str, calib_us: float,
                 total_us: float, root: str) -> None:
    """Land benchmarks/results/BENCH_<name>.json so the perf trajectory
    is tracked across PRs, not just printed."""
    out_dir = os.path.join(root, "benchmarks", "results")
    os.makedirs(out_dir, exist_ok=True)
    backend, _, device = meta.partition("/")
    rec = {
        "bench": name,
        "backend": backend or "unknown",
        "device": device or "unknown",
        "calib_us": round(calib_us, 3),
        "total_us": round(total_us, 1),
        "rows": rows,
    }
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"{name}/record,0,{os.path.relpath(path, root)}", flush=True)


def main() -> None:
    args = sys.argv[1:]
    if len(args) == 2 and args[0] == "--worker":
        _run_inprocess(args[1])
        return

    only = args[0] if args else None
    print("name,us_per_call,derived")
    failures = 0
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), root,
                    env.get("PYTHONPATH", "")) if p)
    for name, mod in BENCHES:
        if only and only != name:
            continue
        t0 = time.perf_counter()
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--worker", mod],
            env=env, cwd=root, capture_output=True, text=True)
        rows, meta, calib_us = [], "", 0.0
        for line in proc.stdout.splitlines():
            if line.count(",") < 2 or line.startswith("name,"):
                continue
            if line.startswith("_meta/backend,"):
                meta = line.split(",", 2)[2]
                continue
            if line.startswith("_meta/calib,"):
                try:
                    calib_us = float(line.split(",", 2)[1])
                except ValueError:
                    pass
                continue
            print(line, flush=True)
            row = _parse_row(line)
            if row is not None:
                rows.append(row)
        if proc.returncode != 0:
            failures += 1
            err = proc.stderr.strip().splitlines()
            print(f"{name}/ERROR,0,{err[-1][:160] if err else 'unknown'}",
                  flush=True)
        total_us = (time.perf_counter() - t0) * 1e6
        if proc.returncode == 0:
            _perf_record(name, rows, meta, calib_us, total_us, root)
        print(f"{name}/total,{total_us:.0f},done", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
