# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
# Each benchmark runs in its OWN subprocess: XLA:CPU's JIT accumulates
# dylib/symbol state over hundreds of compilations and eventually fails
# with "Failed to materialize symbols" in a long-lived process; process
# isolation keeps every table reproducible.
#
# The ``kernel`` bench additionally lands a machine-readable perf record
# at benchmarks/results/BENCH_kernel.json so the perf trajectory is
# tracked across PRs, not just printed.
import json
import os
import subprocess
import sys
import time

BENCHES = [
    ("table1", "bench_pruning_rate"),
    ("fig10", "bench_block_size"),
    ("table2", "bench_compare_schemes"),
    ("fig12", "bench_utilization"),
    ("table3", "bench_latency"),
    ("kernel", "bench_kernel"),
    ("roofline", "bench_roofline"),
]


def _run_inprocess(mod_name: str) -> None:
    import importlib

    import jax

    # metadata row for the coordinator's perf record — describes THIS
    # worker (the coordinator stays jax-free by design, see header)
    print(f"_meta/backend,0,{jax.default_backend()}"
          f"/{jax.devices()[0].device_kind}", flush=True)
    mod = importlib.import_module(f"benchmarks.{mod_name}")
    mod.run()


def _perf_record(name: str, rows: list[dict], meta: str,
                 total_us: float, root: str) -> None:
    """Land benchmarks/results/BENCH_<name>.json so the perf trajectory
    is tracked across PRs, not just printed."""
    out_dir = os.path.join(root, "benchmarks", "results")
    os.makedirs(out_dir, exist_ok=True)
    backend, _, device = meta.partition("/")
    rec = {
        "bench": name,
        "backend": backend or "unknown",
        "device": device or "unknown",
        "total_us": round(total_us, 1),
        "rows": rows,
    }
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"{name}/record,0,{os.path.relpath(path, root)}", flush=True)


def main() -> None:
    args = sys.argv[1:]
    if len(args) == 2 and args[0] == "--worker":
        _run_inprocess(args[1])
        return

    only = args[0] if args else None
    print("name,us_per_call,derived")
    failures = 0
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), root,
                    env.get("PYTHONPATH", "")) if p)
    for name, mod in BENCHES:
        if only and only != name:
            continue
        t0 = time.perf_counter()
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--worker", mod],
            env=env, cwd=root, capture_output=True, text=True)
        rows, meta = [], ""
        for line in proc.stdout.splitlines():
            if line.count(",") < 2 or line.startswith("name,"):
                continue
            if line.startswith("_meta/backend,"):
                meta = line.split(",", 2)[2]
                continue
            print(line, flush=True)
            if name != "kernel":
                continue
            rname, us, derived = line.split(",", 2)
            try:
                rows.append({"name": rname, "us_per_call": float(us),
                             "derived": derived})
            except ValueError:
                pass
        if proc.returncode != 0:
            failures += 1
            err = proc.stderr.strip().splitlines()
            print(f"{name}/ERROR,0,{err[-1][:160] if err else 'unknown'}",
                  flush=True)
        total_us = (time.perf_counter() - t0) * 1e6
        if name == "kernel" and proc.returncode == 0:
            _perf_record(name, rows, meta, total_us, root)
        print(f"{name}/total,{total_us:.0f},done", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
