"""Paper Table 3: end-to-end per-frame latency of CSB-RNN inference.

Cycle model @ 200 MHz with 512 PEs (paper: 4x4 groups x 4x4 PEs plus the
dataflow units), on the paper's benchmark layer dims with their reported
pruning rates. Faster-than-realtime criterion: << 500 us/frame (speech).
Also reports the macro-program occupancy (VLIW schedule) per cell type.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.cells import make_cell
from repro.configs import PAPER_MODELS
from repro.core import CSBSpec, csb_project
from repro.engine.isa import compile_macro
from repro.engine.simulator import (
    EngineConfig, dense_latency_us, simulate_matrix,
)
from .common import csb_encode_weight, emit, synthetic_rnn_weight

# paper-reported CSB lossless rates (Table 2-ish) used as prune targets
RATES = {"MT1": 12.5, "SR1": 13.0, "SR2": 20.0}


def _gate_count(cell: str) -> int:
    return {"lstm": 4, "lstmp": 4, "gru": 3, "ligru": 2}[cell]


def run() -> None:
    e = EngineConfig(K=4, L=4, P=4, Q=4, freq_mhz=200.0)
    key = jax.random.PRNGKey(11)
    for abbr in ("MT1", "SR1", "SR2"):
        pm = PAPER_MODELS[abbr]
        cr = RATES[abbr]
        rate = 1.0 - 1.0 / cr
        total_us = 0.0
        dense_us = 0.0
        t0 = time.perf_counter()
        for lcfg in pm.layers:
            gates = _gate_count(lcfg.cell)
            hid = lcfg.proj or lcfg.n_hidden
            for (rows, cols) in [(lcfg.n_hidden, lcfg.n_input)] * gates + \
                                [(lcfg.n_hidden, hid)] * gates:
                key, sub = jax.random.split(key)
                w = synthetic_rnn_weight(sub, (rows, cols), imbalance=1.5)
                spec = CSBSpec(bm=32, bn=32, prune_rate=rate)
                csb = csb_encode_weight(csb_project(w, spec), spec)
                total_us += simulate_matrix(csb, e, "2d").latency_us
                dense_us += dense_latency_us((rows, cols), e)
        dt = (time.perf_counter() - t0) * 1e6
        emit(f"table3/{abbr}/csb_latency_us", dt, f"{total_us:.2f}")
        emit(f"table3/{abbr}/dense_latency_us", 0.0, f"{dense_us:.2f}")
        emit(f"table3/{abbr}/speedup", 0.0, f"{dense_us / total_us:.2f}x")
        emit(f"table3/{abbr}/faster_than_realtime", 0.0,
             str(total_us < 500.0))
    # VLIW macro schedules: MVM-bound occupancy per cell type
    for kind in ("lstm", "gru", "lstmp", "ligru"):
        prog = compile_macro(make_cell(kind, 256, 1024, proj_dim=512))
        occ = prog.occupancy()
        emit(f"table3/macro/{kind}", 0.0,
             f"slots={prog.length};csb_occ={occ['CSB-Engine']:.2f}")


if __name__ == "__main__":
    run()
