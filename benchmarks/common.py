"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cells import init_params, make_cell, rnn_scan
from repro.core import (
    CSBMatrix, CSBSpec, admm_finalize, admm_init, admm_penalty, admm_update,
    csb_masks,
)
from repro.data import SeqClassifyTask

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def timed(fn, *args, warmup=1, iters=3, reduce="mean"):
    """(result, us_per_call). ``reduce="mean"`` amortizes one timed loop
    (cheap, default); ``reduce="min"`` times each call separately and
    takes the best — the noise-robust statistic for rows a CI perf gate
    compares across runs (throttling spikes inflate mean, never min)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    if reduce == "min":
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            out = fn(*args)
            jax.block_until_ready(out)
            best = min(best, time.perf_counter() - t0)
        return out, best * 1e6
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / iters * 1e6


def train_rnn_classifier(cell_kind="gru", hidden=32, vocab=16, steps=60,
                         specs=None, seed=0, admm_every=10, rho=0.02):
    """Small task-trained RNN used across pruning benchmarks.

    Returns (cell, params, eval_acc_fn)."""
    task = SeqClassifyTask(vocab=vocab, n_classes=4, seq_len=12, seed=seed)
    cell = make_cell(cell_kind, vocab, hidden)
    key = jax.random.PRNGKey(seed)
    params = init_params(cell, key)
    params["emb"] = jax.random.normal(key, (vocab, vocab)) * 0.3
    params["out"] = jax.random.normal(key, (hidden, 4)) * 0.3

    def loss_fn(p, toks, labels, admm_state=None):
        xs = p["emb"][toks].transpose(1, 0, 2)
        ys, _ = rnn_scan(cell, {k: v for k, v in p.items()
                                if k not in ("emb", "out")}, xs)
        logits = ys[-1] @ p["out"]
        ll = jax.nn.log_softmax(logits)
        loss = -jnp.mean(jnp.take_along_axis(ll, labels[:, None], 1))
        if admm_state is not None:
            loss = loss + admm_penalty(p, admm_state, specs)
        return loss

    admm_state = admm_init(params, specs, rho=rho) if specs else None
    # jit once per training run: eager grad floods XLA:CPU's JIT with
    # thousands of micro-compilations and eventually exhausts its symbol
    # tables ("Failed to materialize symbols").
    grad = jax.jit(jax.grad(loss_fn))
    for step in range(steps):
        b = task.batch(step, 32)
        g = grad(params, jnp.asarray(b["tokens"]),
                 jnp.asarray(b["labels"]), admm_state)
        params = jax.tree.map(lambda w, gg: w - 0.05 * gg, params, g)
        if specs and (step + 1) % admm_every == 0:
            admm_state = admm_update(params, admm_state, specs)
    if specs:
        params = admm_finalize(params, specs)

    def accuracy(p=params):
        correct = total = 0
        for step in range(200, 204):
            b = task.batch(step, 64)
            xs = p["emb"][jnp.asarray(b["tokens"])].transpose(1, 0, 2)
            ys, _ = rnn_scan(cell, {k: v for k, v in p.items()
                                    if k not in ("emb", "out")}, xs)
            pred = jnp.argmax(ys[-1] @ p["out"], -1)
            correct += int((pred == jnp.asarray(b["labels"])).sum())
            total += 64
        return correct / total

    return cell, params, accuracy


def csb_encode_weight(w, spec: CSBSpec) -> CSBMatrix:
    rm, cm = csb_masks(w, spec)
    return CSBMatrix.from_dense(np.asarray(w), spec.bm, spec.bn,
                                np.asarray(rm), np.asarray(cm))


def synthetic_rnn_weight(key, shape, imbalance=1.5, diag_boost=3.0):
    """Weight with RNN-like heavy-tailed, block-imbalanced magnitudes,
    including the diagonal-dense structure the paper singles out (§6.3.2:
    'diagonal dense matrix exists... blocks on the matrix diagonal
    contain significant workload'). Used where training full-size paper
    models is infeasible offline."""
    k1, k2, k3 = jax.random.split(key, 3)
    base = jax.random.normal(k1, shape)
    rows = jnp.exp(jax.random.normal(k2, (shape[0], 1)) * imbalance * 0.4)
    cols = jnp.exp(jax.random.normal(k3, (1, shape[1])) * imbalance * 0.25)
    w = base * rows * cols
    # diagonal band boost
    ii = jnp.arange(shape[0])[:, None]
    jj = jnp.arange(shape[1])[None, :]
    band = jnp.abs(ii * shape[1] - jj * shape[0]) < 0.04 * shape[0] * shape[1]
    return w * jnp.where(band, diag_boost, 1.0)
