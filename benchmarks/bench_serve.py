"""Serve-path perf: continuous-batching throughput + frame latency.

The serve table is GATED in CI (benchmarks/diff.py: serve rows whose
name contains ``/us_per``, same >25% calibration-normalized rule as the
kernel ``/mvm`` rows — see the module docstring there), so rows use the
noise-robust min-of-N statistic:

  serve/continuous/us_per_token — wall-us per generated token through
      ``serve_continuous`` (mixed-length prompts arriving over time,
      slot eviction + refill mid-decode); derived = tokens/sec.
  serve/paged/us_per_token     — the same trace through the paged
      cache (block-pool allocator + page-table decode + pow2 prefill
      bucketing); derived = tokens/sec. Gates the page-indirection
      overhead on the per-token path.
  serve/generate/us_per_token  — the fixed-batch ``generate`` loop on
      the same model (the decode_32k shape, scaled down); derived =
      tokens/sec.
  serve/prefix/us_per_token    — a shared-system-prompt trace through
      the paged cache with ``prefix_cache=True`` (radix-trie admission
      + CoW partial prefill); derived = tokens/sec. The run asserts
      token parity with the non-shared engine, nonzero prefix hits and
      a real prefill-token reduction before emitting, so the row can
      never report a number the sharing didn't earn.
  serve/disagg/us_per_token    — the paged trace through
      ``serve_disaggregated`` (prefill tier -> PageHandoff -> decode
      tier); derived = tokens/sec. Token parity with the single-engine
      paged run is asserted before emitting, so the row gates the
      handoff overhead, never a divergent computation.
  serve/speculative/us_per_token — the same paged trace decoded
      draft-then-verify (``speculative=True``: CSB-pruned self-draft
      proposes ``spec_k`` tokens, the target verifies them in one
      multi-position decode step); derived = tokens/sec. Token parity
      with the plain paged run is asserted before emitting (greedy
      trace — rejection sampling is exact at T=0), so the row gates
      the draft+verify overhead, never a divergent computation.
  serve/frames/us_per_frame    — ``rnn_serve_frames`` over a
      CSB-compressed LSTM (the paper's faster-than-realtime workload);
      derived = the realtime criterion check (<500 us is only
      meaningful on real hardware; CPU-interpret numbers gate only
      against themselves).
  serve/frames/p99_us_per_frame — tail frame latency from a separate
      per-frame-blocking pass (the realtime criterion cares about the
      worst frame; blocking serializes the pipeline, so it must not
      pollute the gated mean row). Gated by diff.py's ABSOLUTE
      realtime budget (--realtime-budget-us, default 500us normalized)
      rather than the relative rule: a p99 drifting within budget is
      fine, one crossing the frame deadline is a failure.

Informational rows (never gate: us_per_call = 0): achieved slot
occupancy, the scheduler's prefill/decode-step counts, the paged
memory footprint (peak pool tokens vs the contiguous cache the same
trace would pin), the prefix-sharing counters, the disagg handoff
counters, the speculative acceptance counters
(``serve/speculative/acceptance`` and the per-prune-rate
acceptance/speedup sweep ``serve/speculative/speedup_vs_prune``),
``serve/router/slo_attainment`` (fleet-wide p99 latency +
deadline attainment per routing policy from the trace-driven
multi-replica dryrun — host-side replay, no device work, so it never
belongs in a gated row), and the ``serve/obs/*`` lane: request-lifecycle percentiles (TTFT, queue wait, per-step wall)
from one TRACED run of the same trace, the engine's compile-vs-steady
throughput split, and the measured tracing overhead (traced vs
untraced us/token — the gated rows above always run with tracing off,
this row documents what turning it on costs).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.cells import init_params as cell_init, make_cell
from repro.core import CSBSpec, csb_masks, csb_project, padded_csb_from_dense
from repro.models import ModelConfig, init_params
from repro.serve import EngineConfig, Request, generate, \
    rnn_serve_frames, serve_continuous, serve_disaggregated
from repro.serve.router import make_arrival_trace, simulate_replicas

from .common import emit

CFG = ModelConfig(name="serve-bench", mixer="attn", ffn="swiglu",
                  n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
                  d_ff=128, vocab=256, dtype="float32", logit_chunk=32,
                  remat=False)
N_SLOTS = 4


def _trace(rng) -> list[Request]:
    """Mixed-length prompts arriving over time: 3 waves x 4 requests,
    one long straggler per wave (4x the short totals) — the length skew
    that makes the contiguous cache pay worst-case for every slot."""
    reqs = []
    for i in range(12):
        if i % 4 == 0:
            plen, new = int(rng.integers(20, 25)), int(rng.integers(20, 25))
        else:
            plen, new = int(rng.integers(4, 13)), int(rng.integers(6, 13))
        reqs.append(Request(
            rid=i, tokens=rng.integers(0, CFG.vocab, size=plen),
            max_new_tokens=new, arrival=(i // 4) * 4))
    return reqs


def run() -> None:
    params = init_params(jax.random.PRNGKey(0), CFG)
    rng = np.random.default_rng(17)
    reqs = _trace(rng)

    # -- continuous batching (min-of-3 after a compile warmup) -------------
    ccfg = EngineConfig(n_slots=N_SLOTS)
    serve_continuous(params, CFG, reqs, ccfg)               # warmup
    best = None
    for _ in range(3):
        r = serve_continuous(params, CFG, reqs, ccfg)
        if best is None or r.wall_s < best.wall_s:
            best = r
    ntok = best.stats["generated_tokens"]
    emit("serve/continuous/us_per_token", best.wall_s * 1e6 / ntok,
         f"{ntok / best.wall_s:.1f}")
    emit("serve/continuous/occupancy", 0.0,
         f"{best.stats['occupancy']:.4f}")
    emit("serve/continuous/steps", 0.0,
         f"prefills={best.stats['prefills']};"
         f"decode={best.stats['decode_steps']}")

    # -- paged cache, same trace (min-of-3 after a compile warmup) ---------
    pcfg = EngineConfig(n_slots=N_SLOTS, paged=True, page_size=8)
    serve_continuous(params, CFG, reqs, pcfg)               # warmup
    bestp = None
    for _ in range(3):
        r = serve_continuous(params, CFG, reqs, pcfg)
        if bestp is None or r.wall_s < bestp.wall_s:
            bestp = r
    ntok = bestp.stats["generated_tokens"]
    emit("serve/paged/us_per_token", bestp.wall_s * 1e6 / ntok,
         f"{ntok / bestp.wall_s:.1f}")
    pg = bestp.stats["paging"]
    contiguous_tokens = N_SLOTS * bestp.stats["cache_len"]
    emit("serve/paged/peak_cache_tokens", 0.0,
         f"paged={pg['peak_pages'] * pg['page_size']};"
         f"contiguous={contiguous_tokens};"
         f"frag={pg['internal_fragmentation']}")

    # -- prefix cache: shared-system-prompt trace --------------------------
    sys_p = rng.integers(0, CFG.vocab, size=18)
    preqs = []
    for i in range(12):
        tail = rng.integers(0, CFG.vocab, size=int(rng.integers(2, 8)))
        preqs.append(Request(
            rid=i, tokens=np.concatenate([sys_p, tail]),
            max_new_tokens=int(rng.integers(6, 13)), arrival=(i // 4) * 4))
    xcfg = EngineConfig(n_slots=N_SLOTS, paged=True, page_size=8,
                        prefix_cache=True)
    off = serve_continuous(params, CFG, preqs, pcfg)
    serve_continuous(params, CFG, preqs, xcfg)               # warmup
    bestx = None
    for _ in range(3):
        r = serve_continuous(params, CFG, preqs, xcfg)
        if bestx is None or r.wall_s < bestx.wall_s:
            bestx = r
    assert bestx.tokens == off.tokens, \
        "prefix-cache run diverged from the non-shared engine"
    assert bestx.stats["prefix_hits"] > 0, "trace produced no prefix hits"
    assert bestx.stats["prefill_tokens"] < off.stats["prefill_tokens"], \
        "prefix cache did not reduce prefill compute"
    ntok = bestx.stats["generated_tokens"]
    emit("serve/prefix/us_per_token", bestx.wall_s * 1e6 / ntok,
         f"{ntok / bestx.wall_s:.1f}")
    emit("serve/prefix/sharing", 0.0,
         f"hits={bestx.stats['prefix_hits']};"
         f"shared_pages={bestx.stats['shared_pages']};"
         f"prefill_tokens={bestx.stats['prefill_tokens']}"
         f"vs{off.stats['prefill_tokens']};"
         f"cow={bestx.stats['paging']['cow_copies']}")

    # -- disaggregated prefill/decode tiers, same paged trace --------------
    serve_disaggregated(params, CFG, reqs, pcfg)             # warmup
    bestd = None
    for _ in range(3):
        r = serve_disaggregated(params, CFG, reqs, pcfg)
        if bestd is None or r.wall_s < bestd.wall_s:
            bestd = r
    assert bestd.tokens == bestp.tokens, \
        "disaggregated run diverged from the single-engine paged run"
    ntok = bestd.stats["generated_tokens"]
    emit("serve/disagg/us_per_token", bestd.wall_s * 1e6 / ntok,
         f"{ntok / bestd.wall_s:.1f}")
    emit("serve/disagg/handoff", 0.0,
         f"handoffs={bestd.stats['handoffs']};"
         f"pages={bestd.stats['handoff_pages']};"
         f"prefill_tokens={bestd.stats['prefill_tokens']}")

    # -- speculative decoding, same paged trace ----------------------------
    # Greedy trace, so the spec engine must reproduce the plain paged
    # tokens exactly (rejection sampling is token-identical at T=0);
    # the parity assert runs before anything is emitted, so the gated
    # row can never report a number a divergent computation earned.
    scfg = EngineConfig(n_slots=N_SLOTS, paged=True, page_size=8,
                        speculative=True, spec_k=4, draft_prune_rate=0.5)
    serve_continuous(params, CFG, reqs, scfg)                # warmup
    bests = None
    for _ in range(3):
        r = serve_continuous(params, CFG, reqs, scfg)
        if bests is None or r.wall_s < bests.wall_s:
            bests = r
    assert bests.tokens == bestp.tokens, \
        "speculative run diverged from the plain paged engine at T=0"
    ntok = bests.stats["generated_tokens"]
    emit("serve/speculative/us_per_token", bests.wall_s * 1e6 / ntok,
         f"{ntok / bests.wall_s:.1f}")
    sp = bests.stats["speculative"]
    emit("serve/speculative/acceptance", 0.0,
         f"k={sp['spec_k']};prune={sp['draft_prune_rate']};"
         f"rate={sp['acceptance_rate']:.4f};rounds={sp['rounds']};"
         f"tokens_per_round={ntok / max(sp['rounds'], 1):.3f}")
    # acceptance + speedup vs draft prune rate (informational: on CPU
    # the CSB-pruned draft runs the same dense matmuls as the target,
    # so "speedup" here isolates the verify-batching win, not the
    # draft-compression win the paper's hardware realizes)
    parts = []
    for rate in (0.0, 0.5, 0.875):
        rcfg = EngineConfig(n_slots=N_SLOTS, paged=True, page_size=8,
                            speculative=True, spec_k=4,
                            draft_prune_rate=rate)
        serve_continuous(params, CFG, reqs, rcfg)            # warmup
        bb = None
        for _ in range(2):
            r = serve_continuous(params, CFG, reqs, rcfg)
            if bb is None or r.wall_s < bb.wall_s:
                bb = r
        assert bb.tokens == bestp.tokens, \
            f"speculative run (prune={rate}) diverged at T=0"
        st = bb.stats["speculative"]
        nt = bb.stats["generated_tokens"]
        parts.append(f"prune{rate}:accept={st['acceptance_rate']:.3f}"
                     f",speedup={bestp.wall_s / bb.wall_s:.3f}x")
    emit("serve/speculative/speedup_vs_prune", 0.0, ";".join(parts))

    # -- router dryrun: fleet SLO attainment per policy --------------------
    # Host-side replay (simulate_admission), so the row is informational:
    # it documents what the routing policies deliver on a deadline-
    # carrying Poisson trace, not a device timing.
    rtrace = make_arrival_trace(np.random.default_rng(23), 24,
                                vocab=CFG.vocab, mean_gap_steps=0.5,
                                deadline_slack=2.0, step_time_us=1.0)
    parts = []
    for pol in ("round_robin", "least_loaded"):
        s = simulate_replicas(rtrace, 2, policy=pol, n_slots=N_SLOTS,
                              step_time_us=1.0)
        parts.append(f"{pol}={s['slo_attainment']:.4f}"
                     f"(p99={s['latency_us']['p99']:.1f}us)")
    emit("serve/router/slo_attainment", 0.0, ";".join(parts))

    # -- fixed-batch generate ----------------------------------------------
    prompts = jax.numpy.asarray(
        rng.integers(0, CFG.vocab, size=(8, 12)), dtype="int32")
    gcfg = EngineConfig(max_new_tokens=8)
    generate(params, CFG, prompts, gcfg)                    # warmup
    best_s = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        out = generate(params, CFG, prompts, gcfg)
        jax.block_until_ready(out)
        best_s = min(best_s, time.perf_counter() - t0)
    ntok = prompts.shape[0] * gcfg.max_new_tokens
    emit("serve/generate/us_per_token", best_s * 1e6 / ntok,
         f"{ntok / best_s:.1f}")

    # -- frame-by-frame CSB-RNN serving ------------------------------------
    cell = make_cell("lstm", 64, 128)
    wparams = cell_init(cell, jax.random.PRNGKey(2))
    spec = CSBSpec(bm=16, bn=16, prune_rate=0.875)
    csb_params = {}
    for k, w in wparams.items():
        if w.ndim == 2:
            z = csb_project(w, spec)
            rm, cm = csb_masks(w, spec)
            csb_params[k] = padded_csb_from_dense(
                np.asarray(z), 16, 16, row_mask=np.asarray(rm),
                col_mask=np.asarray(cm))
        else:
            csb_params[k] = w
    frames = jax.random.normal(jax.random.PRNGKey(3), (24, 4, 64))
    best_us = float("inf")
    frame_us = None
    fcfg = EngineConfig(frame_warmup=1, collect_frame_times=True)
    for _ in range(3):
        _, _, us, ft = rnn_serve_frames(cell, csb_params, frames,
                                        config=fcfg)
        if us < best_us:
            best_us, frame_us = us, ft
    emit("serve/frames/us_per_frame", best_us,
         f"realtime_500us={best_us < 500.0}")
    # tail latency (per-frame-blocking pass). The name has no "/us_per"
    # segment so the relative /us_per gate never fires on it; instead
    # diff.py's --realtime-row matches the "p99" and holds the value to
    # the absolute --realtime-budget-us frame deadline.
    p99 = float(np.percentile(frame_us, 99))
    emit("serve/frames/p99_us_per_frame", p99,
         f"realtime_500us={p99 < 500.0}")

    # -- observability lane (informational; tracing ON for these only) -----
    # Jit caches are warm from the gated runs above, so the traced run
    # measures steady-state instrumented serving, not compiles. None of
    # these names contain "/us_per" and all carry us_per_call=0, so the
    # diff.py relative gate never fires on them.
    from repro import obs
    from repro.obs import metrics as obs_metrics, trace as obs_trace

    obs.enable_all()
    best_on = None
    for _ in range(3):
        r = serve_continuous(params, CFG, reqs, ccfg)
        if best_on is None or r.wall_s < best_on.wall_s:
            best_on = r
    reg = obs_metrics.get()
    ttft = reg.histogram("serve/req/ttft_us")
    qw = reg.histogram("serve/req/queue_wait_us")
    stepw = reg.histogram("serve/step/wall_us")
    emit("serve/obs/ttft_us", 0.0,
         f"p50={ttft.percentile(50):.1f};p99={ttft.percentile(99):.1f}")
    emit("serve/obs/queue_wait_us", 0.0,
         f"p50={qw.percentile(50):.1f};p99={qw.percentile(99):.1f}")
    emit("serve/obs/decode_step_us", 0.0,
         f"p50={stepw.percentile(50):.1f};p99={stepw.percentile(99):.1f}")
    emit("serve/obs/throughput_split", 0.0,
         f"compile_s={best_on.stats['compile_time_s']};"
         f"steady_tps={best_on.stats['steady_tokens_per_sec']};"
         f"blended_tps={best_on.stats['tokens_per_sec']}")
    n_ev = len(obs_trace.get().events())
    obs.disable_all()
    # overhead: the traced best-of-3 vs the untraced best-of-3 (`best`)
    # of the identical trace — both steady-state, same compiled code
    ntok = best.stats["generated_tokens"]
    on_us = best_on.wall_s * 1e6 / ntok
    off_us = best.wall_s * 1e6 / ntok
    emit("serve/obs/tracing_overhead", 0.0,
         f"on={on_us:.2f}us/tok;off={off_us:.2f}us/tok;"
         f"ratio={on_us / off_us:.3f};events={n_ev}")


if __name__ == "__main__":
    run()
