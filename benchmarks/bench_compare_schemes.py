"""Paper Table 2: CSB vs prior compression schemes at matched accuracy.

Same trained model, same lossless band, four schemes: CSB (ours),
non-structured magnitude (upper bound), bank-balanced, whole-matrix
row/column. Reports the achieved compression of each.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import (
    CSBSpec, bank_balanced_project, density, magnitude_project,
    row_column_project,
)
from .common import emit, train_rnn_classifier


def _acc(cell_kind, params, seed):
    import jax.numpy as jnp
    from repro.cells import make_cell, rnn_scan
    from repro.data import SeqClassifyTask
    task = SeqClassifyTask(vocab=16, n_classes=4, seq_len=12, seed=seed)
    cell = make_cell(cell_kind, 16, 32)
    correct = total = 0
    for step in range(200, 204):
        b = task.batch(step, 64)
        xs = params["emb"][jnp.asarray(b["tokens"])].transpose(1, 0, 2)
        ys, _ = rnn_scan(cell, {k: v for k, v in params.items()
                                if k not in ("emb", "out")}, xs)
        pred = jnp.argmax(ys[-1] @ params["out"], -1)
        correct += int((pred == jnp.asarray(b["labels"])).sum())
        total += 64
    return correct / total


def _best_rate(dense_params, target, project, cell_kind, seed,
               rates=(0.875, 0.75, 0.5, 0.25)):
    for rate in rates:
        pruned = dict(dense_params)
        for k, w in dense_params.items():
            if hasattr(w, "ndim") and w.ndim == 2 and k not in ("emb", "out"):
                pruned[k] = project(w, rate)
        if _acc(cell_kind, pruned, seed) >= target:
            return 1 / (1 - rate)
    return 1.0


def run() -> None:
    seed = 3
    cell_kind = "gru"
    _, dense_params, acc_fn = train_rnn_classifier(cell_kind, seed=seed,
                                                   steps=80)
    target = acc_fn() - 0.05

    schemes = {
        "nonstructured": lambda w, r: magnitude_project(w, r),
        "csb_b8": lambda w, r: _csb(w, r, 8),
        "bank_balanced": lambda w, r: bank_balanced_project(w, r, bank=16),
        "row_column": lambda w, r: row_column_project(w, r),
    }
    results = {}
    for name, proj in schemes.items():
        t0 = time.perf_counter()
        cr = _best_rate(dense_params, target, proj, cell_kind, seed)
        dt = (time.perf_counter() - t0) * 1e6
        results[name] = cr
        emit(f"table2/{name}/lossless_cr", dt, f"{cr:.2f}x")
    # the paper's ordering: nonstructured >= csb >= bank >= row/col
    if results["csb_b8"] >= results["row_column"]:
        emit("table2/csb_vs_rowcol", 0.0,
             f"{results['csb_b8'] / max(results['row_column'], 1):.2f}x_better")


def _csb(w, rate, bm):
    from repro.core import csb_project
    return csb_project(w, CSBSpec(bm=bm, bn=bm, prune_rate=rate))


if __name__ == "__main__":
    run()
