"""Beyond-paper: the 40-cell roofline table from the dry-run artifacts.

Reads benchmarks/results/dryrun/*.json (produced by
``python -m repro.launch.dryrun --sweep --both-meshes``) and emits the
per-cell roofline terms. No recompilation here — this is the reporting
stage that EXPERIMENTS.md §Roofline is generated from.
"""
from __future__ import annotations

import glob
import json
import os

from .common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_records(mesh: str = "pod16x16", tag: str | None = None):
    recs = []
    for f in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        base = os.path.basename(f)[:-5]
        parts = base.split("__")
        if len(parts) == 3 and tag is None:
            pass
        elif len(parts) == 4 and tag == parts[3]:
            pass
        else:
            continue
        rec = json.load(open(f))
        if rec.get("mesh") == mesh:
            recs.append(rec)
    return recs


def run() -> None:
    recs = load_records("pod16x16")
    if not recs:
        emit("roofline/missing", 0.0, "run_dryrun_sweep_first")
        return
    ok = [r for r in recs if r["status"] == "ok"]
    sk = [r for r in recs if r["status"] == "skipped"]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"])):
        rl = r["roofline"]
        dom_t = max(rl["t_compute"], rl["t_memory"], rl["t_collective"])
        emit(
            f"roofline/{r['arch']}/{r['shape']}",
            dom_t * 1e6,
            f"dom={rl['dominant']};tC={rl['t_compute']:.3g};"
            f"tM={rl['t_memory']:.3g};tX={rl['t_collective']:.3g};"
            f"useful={rl['useful_ratio']:.2f};"
            f"peak={r['memory']['peak_bytes_per_device']/1e9:.1f}GB",
        )
    emit("roofline/summary", 0.0,
         f"{len(ok)}_cells_ok;{len(sk)}_skipped")
    multi = [r for r in load_records("pod2x16x16") if r["status"] == "ok"]
    emit("roofline/multipod", 0.0, f"{len(multi)}_cells_ok_512chips")


if __name__ == "__main__":
    run()
