"""Paper Table 1: lossless CSB pruning rate via the progressive flow.

Offline stand-in: small task-trained RNNs (synthetic datasets — see
DESIGN.md §6). For each model we run Algorithm 1's progressive search with
CSB pruning AND with the non-structured magnitude baseline (the paper's
"theoretical optimum" column) and report both compression ratios.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import (
    CSBSpec, ProgressivePruner, density, magnitude_project,
)
from .common import emit, train_rnn_classifier


def _lossless_search(cell_kind, project_kind, seed=0, bm=8):
    """Progressive search; returns (best compression ratio, iters)."""
    _, dense_params, acc_fn = train_rnn_classifier(cell_kind, seed=seed)
    target = acc_fn() - 0.05            # lossless band (synthetic-task noise)

    ctl = ProgressivePruner(init_pr=0.25, init_step=0.25)
    guard = 0
    while not ctl.done and guard < 8:
        guard += 1
        rate = ctl.prune_rate
        if project_kind == "csb":
            specs = jax.tree.map(lambda _: None, dense_params)
            spec = CSBSpec(bm=bm, bn=bm, prune_rate=rate)
            for k, w in dense_params.items():
                if hasattr(w, "ndim") and w.ndim == 2 \
                        and k not in ("emb", "out"):
                    specs[k] = spec
            _, pruned, acc2 = train_rnn_classifier(
                cell_kind, specs=specs, seed=seed, steps=120)
            ok = acc2() >= target
        else:  # magnitude one-shot + short retrain-free eval
            pruned = dict(dense_params)
            for k, w in dense_params.items():
                if hasattr(w, "ndim") and w.ndim == 2 \
                        and k not in ("emb", "out"):
                    pruned[k] = magnitude_project(w, rate)
            _, _, accf = train_rnn_classifier(cell_kind, seed=seed, steps=0)
            ok = _acc_with(cell_kind, pruned, seed) >= target
        ctl.update(ok)
    return ctl.best_compression, guard


def _acc_with(cell_kind, params, seed):
    from repro.cells import make_cell, rnn_scan
    import jax.numpy as jnp
    from repro.data import SeqClassifyTask
    task = SeqClassifyTask(vocab=16, n_classes=4, seq_len=12, seed=seed)
    cell = make_cell(cell_kind, 16, 32)
    correct = total = 0
    for step in range(200, 204):
        b = task.batch(step, 64)
        xs = params["emb"][jnp.asarray(b["tokens"])].transpose(1, 0, 2)
        ys, _ = rnn_scan(cell, {k: v for k, v in params.items()
                                if k not in ("emb", "out")}, xs)
        pred = jnp.argmax(ys[-1] @ params["out"], -1)
        correct += int((pred == jnp.asarray(b["labels"])).sum())
        total += 64
    return correct / total


def run() -> None:
    for cell_kind in ("gru", "lstm"):
        t0 = time.perf_counter()
        cr_csb, iters = _lossless_search(cell_kind, "csb")
        dt = (time.perf_counter() - t0) * 1e6
        emit(f"table1/{cell_kind}/csb_lossless_rate", dt,
             f"{cr_csb:.2f}x_in_{iters}_iters")
        t0 = time.perf_counter()
        cr_mag, _ = _lossless_search(cell_kind, "magnitude")
        dt = (time.perf_counter() - t0) * 1e6
        emit(f"table1/{cell_kind}/nonstructured_rate", dt, f"{cr_mag:.2f}x")


if __name__ == "__main__":
    run()
