"""Perf-regression diff: fresh BENCH_*.json vs the committed baseline.

``benchmarks/results/baseline/`` holds a committed snapshot of every
table's record (refreshed with ``--update-baseline``). CI runs the
benches, then this diff; the job FAILS when a gated table's
``us_per_call`` regresses more than ``--threshold`` (default 25%)
after machine-speed normalization.

Cross-machine normalization: absolute timings on a GitHub runner and
the machine that committed the baseline differ, so raw ratios would
gate on hardware, not code. Every record carries ``calib_us`` — a
fixed numpy matmul timed in the same worker process — giving a
normalized ratio ``(us_fresh / us_base) / (calib_fresh / calib_base)``
next to the raw one. Calibration itself is noisy on shared runners, so
a row FAILS only when BOTH ratios exceed the threshold: the raw ratio
filters out calibration misreads (a scale blip cannot fail CI by
itself), the normalized ratio filters out genuinely-slower hardware (a
slow runner cancels out). The one combination this forgives — a
machine faster than baseline hiding a small true regression — is the
safe side for a hard CI gate; the diff still prints both ratios. The
scale factor is clamped to [0.2, 5] so a broken calibration can never
swing the verdict by more than that.

The ``kernel`` and ``serve`` tables gate by default (--gate), and
within a gated table only rows matching its --gate-row pattern gate
(default "kernel:/mvm|paged_attn/decode,serve:/us_per" — kernel MVM
and paged-attention decode latencies plus the serve
per-token/per-frame rows; oracle timings, static ratios and occupancy
rows are informational). ``|`` separates alternative substrings for
one table (any match gates); a bare substring (no ":") applies to
every gated table. Serve rows carry latency in ``us_per_call``
(us/token, us/frame) with the throughput (tokens/sec) in ``derived``,
so one rule — "us_per_call regressed >threshold" — gates both a
tokens/sec collapse and a frame-latency blowup. Rows below --min-us
(noise floor) and rows missing from either side never gate, they are
only reported. Numeric ``derived`` drifts are reported informationally
(pruning rates, utilization, tokens/sec).

Usage:
  python benchmarks/diff.py                    # diff + gate, exit 1 on fail
  python benchmarks/diff.py --threshold 0.5
  python benchmarks/diff.py --update-baseline  # bless fresh as baseline
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(ROOT, "benchmarks", "results")
BASELINE = os.path.join(RESULTS, "baseline")


def _load(dir_: str) -> dict[str, dict]:
    recs = {}
    for path in sorted(glob.glob(os.path.join(dir_, "BENCH_*.json"))):
        with open(path) as f:
            rec = json.load(f)
        recs[rec.get("bench", os.path.basename(path))] = rec
    return recs


def _rows_by_name(rec: dict) -> dict[str, dict]:
    return {r["name"]: r for r in rec.get("rows", [])}


def parse_gate_rows(arg: str) -> dict[str, tuple[str, ...]]:
    """``"kernel:/mvm|paged_attn/decode,serve:/us_per"`` -> per-table
    row substring alternatives (``|``-separated; a row gates when ANY
    of its table's substrings matches); a bare entry (no ":") becomes
    the fallback for every table ("*")."""
    out: dict[str, tuple[str, ...]] = {}
    for part in (p for p in arg.split(",") if p):
        table, sep, sub = part.partition(":")
        subs = tuple(s for s in (sub if sep else part).split("|") if s)
        out[table if sep else "*"] = subs
    return out


def diff_records(fresh: dict[str, dict], base: dict[str, dict],
                 threshold: float, gate_tables: set[str],
                 min_us: float,
                 gate_row: str = "kernel:/mvm|paged_attn/decode,"
                 "serve:/us_per",
                 *,
                 realtime_row: str = "serve:p99",
                 realtime_budget_us: float = 500.0,
                 ) -> tuple[list[str], list[str]]:
    """Returns (report lines, gate failures).

    Rows matching ``realtime_row`` gate on an ABSOLUTE budget instead of
    the relative regression rule: tail latency is a realtime contract
    (a frame must render before the next arrives), so a p99 that drifts
    2x while staying comfortably under budget is fine, and one that
    creeps 10% over the line is not. The normalized fresh value must
    stay <= ``realtime_budget_us``; crossing the line when the baseline
    was under it fails outright, and when BOTH sides are over budget
    (budget unreachable on this config) the standard both-ratios
    regression rule takes over so the row still cannot quietly rot."""
    gate_rows = parse_gate_rows(gate_row)
    rt_rows = parse_gate_rows(realtime_row) if realtime_row else {}
    lines: list[str] = []
    failures: list[str] = []
    for name in sorted(set(fresh) | set(base)):
        if name not in base:
            lines.append(f"  [new]     {name}: no baseline record")
            continue
        if name not in fresh:
            lines.append(f"  [missing] {name}: baseline has it, "
                         "fresh run does not")
            continue
        f_rec, b_rec = fresh[name], base[name]
        calib_f = float(f_rec.get("calib_us") or 0.0)
        calib_b = float(b_rec.get("calib_us") or 0.0)
        scale = (calib_f / calib_b) if calib_f > 0 and calib_b > 0 else 1.0
        scale = min(max(scale, 0.2), 5.0)
        gated = name in gate_tables
        lines.append(f"table {name}  (machine scale x{scale:.2f}, "
                     f"{'GATED' if gated else 'informational'})")
        f_rows, b_rows = _rows_by_name(f_rec), _rows_by_name(b_rec)
        for rname in sorted(set(f_rows) | set(b_rows)):
            if rname not in b_rows or rname not in f_rows:
                tag = "new" if rname not in b_rows else "gone"
                lines.append(f"  [{tag}] {rname}")
                continue
            fr, br = f_rows[rname], b_rows[rname]
            fu, bu = float(fr["us_per_call"]), float(br["us_per_call"])
            if bu > 0 and fu > 0:
                raw = fu / bu
                norm = raw / scale
                delta = (norm - 1.0) * 100
                mark = ""
                subs = gate_rows.get(name, gate_rows.get("*", ()))
                row_gates = gated and (
                    not subs or any(s in rname for s in subs))
                # both ratios must regress: raw-only = calibration blip,
                # normalized-only = slower machine (see module docstring)
                if (row_gates and fu >= min_us
                        and min(raw, norm) > 1 + threshold):
                    mark = "  << REGRESSION"
                    failures.append(
                        f"{rname}: {bu:.1f}us -> {fu:.1f}us "
                        f"({raw:.2f}x raw, {norm:.2f}x normalized, "
                        f"threshold {1 + threshold:.2f}x)")
                rt_subs = rt_rows.get(name, rt_rows.get("*", ()))
                if (gated and realtime_budget_us > 0 and rt_subs
                        and any(s in rname for s in rt_subs)):
                    fn = fu / scale
                    if fn > realtime_budget_us and bu <= realtime_budget_us:
                        mark = "  << OVER BUDGET"
                        failures.append(
                            f"{rname}: crossed the realtime budget: "
                            f"{fn:.1f}us normalized > "
                            f"{realtime_budget_us:.0f}us budget "
                            f"(baseline {bu:.1f}us)")
                    elif (fn > realtime_budget_us and not row_gates
                          and fu >= min_us
                          and min(raw, norm) > 1 + threshold):
                        # both sides over budget — relative rule applies
                        mark = "  << REGRESSION"
                        failures.append(
                            f"{rname}: {bu:.1f}us -> {fu:.1f}us, both "
                            f"over the {realtime_budget_us:.0f}us budget "
                            f"({raw:.2f}x raw, {norm:.2f}x normalized, "
                            f"threshold {1 + threshold:.2f}x)")
                if abs(delta) > 5 or mark:
                    lines.append(f"  {rname}: {bu:.1f} -> {fu:.1f} us "
                                 f"({raw:.2f}x raw, {delta:+.0f}% "
                                 f"norm){mark}")
            fd, bd = fr.get("derived"), br.get("derived")
            if (isinstance(fd, (int, float)) and isinstance(bd, (int, float))
                    and bd != 0 and abs(fd / bd - 1) > 0.05):
                lines.append(f"  {rname}: derived {bd} -> {fd}")
    return lines, failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", default=RESULTS)
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--threshold", type=float,
                    default=float(os.environ.get("DIFF_THRESHOLD", 0.25)),
                    help="gated relative regression, 0.25 = +25%%")
    ap.add_argument("--gate", default="kernel,serve",
                    help="comma list of tables whose us_per_call gates")
    ap.add_argument("--gate-row",
                    default="kernel:/mvm|paged_attn/decode,"
                            "serve:/us_per",
                    help="comma list of table:substring row filters "
                         "(| separates alternative substrings); a "
                         "bare substring applies to every gated table "
                         "(empty = every row of a gated table)")
    ap.add_argument("--min-us", type=float, default=50.0,
                    help="rows faster than this never gate (noise floor)")
    ap.add_argument("--realtime-row", default="serve:p99",
                    help="table:substring rows gated on an absolute "
                         "normalized latency budget instead of the "
                         "relative rule (empty disables)")
    ap.add_argument("--realtime-budget-us", type=float,
                    default=float(os.environ.get(
                        "REALTIME_BUDGET_US", 500.0)),
                    help="the budget for --realtime-row rows, in us "
                         "(faster-than-realtime frame deadline)")
    ap.add_argument("--update-baseline", action="store_true")
    args = ap.parse_args()

    fresh = _load(args.fresh)
    if not fresh:
        print(f"no BENCH_*.json under {args.fresh} — run "
              "`python benchmarks/run.py` first")
        return 1

    if args.update_baseline:
        os.makedirs(args.baseline, exist_ok=True)
        for path in glob.glob(os.path.join(args.fresh, "BENCH_*.json")):
            shutil.copy(path, args.baseline)
            print(f"blessed {os.path.basename(path)}")
        return 0

    base = _load(args.baseline)
    if not base:
        print(f"no committed baseline under {args.baseline} — "
              "informational run only (use --update-baseline to create)")
        return 0

    gate_tables = {t for t in args.gate.split(",") if t}
    lines, failures = diff_records(fresh, base, args.threshold,
                                   gate_tables, args.min_us,
                                   gate_row=args.gate_row,
                                   realtime_row=args.realtime_row,
                                   realtime_budget_us=args.realtime_budget_us)
    print("## Benchmark diff vs committed baseline")
    for ln in lines:
        print(ln)
    if failures:
        print(f"\nPERF GATE FAILED ({len(failures)} regression(s) "
              f"> {args.threshold * 100:.0f}% normalized):")
        for f in failures:
            print(f"  {f}")
        return 1
    print("\nperf gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
