#!/usr/bin/env python
"""StableHLO / optimized-HLO structural diff: sharded vs unsharded
single decode step under ``cache_specs``.

The probe the ROADMAP's "sharded hybrid decode drift" item asks for:
``generate``/``serve`` for the hybrid (attn+SSD) mixer on a 2x4 host
mesh can diverge from the unsharded tokens (argmax tie-flips from
changed f32 accumulation order, not a miscompile — see
``tests/test_paged_attn.py::test_hybrid_sharded_decode_drift_2x4``).
This tool lowers ONE jitted decode step twice — params placed by
``csb_shard_specs``, cache by ``cache_specs``, tokens/pos by
``batch_specs``, exactly as the serve engine's ``_Runner`` does, and
once with everything on one device — then diffs the two programs
*structurally*:

* an **op histogram** diff (which ops appear how often on each side:
  the all-reduces/collective-permutes and any reassociated
  reduce/dot chains jump out here), and
* a normalized **line diff** of the texts with SSA ids, locations and
  metadata stripped, so renames don't drown the real changes.

Both the pre-partitioning StableHLO (sharding annotations visible) and
the post-SPMD optimized HLO (what actually runs per device — where
accumulation-order changes live) are dumped to ``--out``.

Usage:
  PYTHONPATH=src python tools/hlo_diff.py                  # hybrid, 2x4
  PYTHONPATH=src python tools/hlo_diff.py --mixer mla --mesh 1x8
  PYTHONPATH=src python tools/hlo_diff.py --stage opt --full-diff

Needs 8 devices; run standalone it forces 8 virtual host devices
itself (before importing jax).
"""
from __future__ import annotations

import argparse
import difflib
import os
import re
import sys
from collections import Counter

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

if "jax" not in sys.modules:
    # honored only pre-import: the probe needs a multi-device host
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8").strip()

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402
from jax.sharding import Mesh, NamedSharding                  # noqa: E402

from repro.dist import (                                      # noqa: E402
    ShardingPolicy, activation_rules, batch_specs, cache_specs,
    csb_shard_specs, fit_spec, use_rules,
)
from repro.models import ModelConfig, init_params             # noqa: E402
from repro.models import lm as LM                             # noqa: E402

# tiny configs mirroring tests/test_paged_attn.py — small enough to
# lower in seconds, structurally identical to the failing shapes
CONFIGS = {
    "attn": dict(mixer="attn", n_heads=4, n_kv=2),
    "mla": dict(mixer="mla", n_heads=2, n_kv=2, kv_lora=16, q_lora=16,
                rope_head_dim=8),
    "hybrid": dict(family="hybrid", mixer="hybrid", n_heads=2, n_kv=2,
                   d_state=8, ssd_headdim=16, ssd_chunk=4, ssd_expand=2,
                   conv_k=4),
}


def make_cfg(mixer: str) -> ModelConfig:
    return ModelConfig(name=f"hlo-diff-{mixer}", ffn="swiglu", n_layers=2,
                       d_model=32, head_dim=16, d_ff=64, vocab=50,
                       dtype="float32", logit_chunk=16, remat=False,
                       **CONFIGS[mixer])


# SSA ids, MLIR locations, HLO metadata/names — renaming noise the
# structural diff must not see
_NOISE = (
    (re.compile(r"%[\w.\-#]+"), "%v"),
    (re.compile(r"\bloc\(.*?\)"), ""),
    (re.compile(r"metadata=\{.*?\}"), ""),
    (re.compile(r'"[^"]*"'), '"_"'),
    (re.compile(r"#\d+"), "#n"),
    (re.compile(r"\s+"), " "),
)

_STABLEHLO_OP = re.compile(r"\b(?:stablehlo|mhlo|func|sdy)\.([\w.]+)")
# optimized HLO:  name = type opcode(...)
_HLO_OP = re.compile(r"=\s*(?:\([^)]*\)|\S+)\s+([a-z][\w\-]*)\(")


def normalize(text: str) -> list[str]:
    out = []
    for line in text.splitlines():
        for pat, rep in _NOISE:
            line = pat.sub(rep, line)
        line = line.strip()
        if line:
            out.append(line)
    return out


def op_histogram(text: str, stage: str) -> Counter:
    pat = _STABLEHLO_OP if stage == "stablehlo" else _HLO_OP
    return Counter(m.group(1) for m in pat.finditer(text))


def _place(tree, mesh, specs):
    return jax.tree.map(
        lambda leaf, sp: jax.device_put(leaf, NamedSharding(mesh, sp)),
        tree, specs)


def lower_decode_step(cfg: ModelConfig, mesh=None,
                      policy: ShardingPolicy | None = None,
                      n_slots: int = 4, cache_len: int = 32):
    """Lower ONE continuous-serve decode step (vector per-slot pos,
    the shapes ``serve_continuous`` compiles). With ``mesh`` the inputs
    are placed exactly as the engine's ``_Runner`` places them."""
    params = init_params(jax.random.PRNGKey(0), cfg)
    cache = LM.init_cache(cfg, n_slots, cache_len, jnp.dtype(cfg.dtype))
    tokens = jnp.ones((n_slots, 1), jnp.int32)
    pos = jnp.full((n_slots,), 7, jnp.int32)
    fn = jax.jit(lambda p, c, t, q: LM.decode_step(p, c, t, q, cfg=cfg))
    if mesh is None:
        return fn.lower(params, cache, tokens, pos)
    policy = policy or ShardingPolicy()
    rules = activation_rules(cfg, mesh, policy)
    params = _place(params, mesh,
                    csb_shard_specs(params, mesh, policy=policy))
    cache = _place(cache, mesh,
                   cache_specs(cfg, cache, mesh, policy))
    bspec = batch_specs(cfg, "decode", mesh)
    tok_sp = fit_spec(bspec["tokens"], tokens.shape, mesh)
    pos_sp = fit_spec(bspec["pos"], pos.shape, mesh)
    if tok_sp is not None:
        tokens = jax.device_put(tokens, NamedSharding(mesh, tok_sp))
    if pos_sp is not None:
        pos = jax.device_put(pos, NamedSharding(mesh, pos_sp))
    with use_rules(rules):
        return fn.lower(params, cache, tokens, pos)


def hlo_texts(lowered, stage: str) -> str:
    if stage == "stablehlo":
        return lowered.as_text()
    return lowered.compile().as_text()


def hlo_diff(mixer: str = "hybrid", mesh_shape: tuple[int, int] = (2, 4),
             stage: str = "opt", out_dir: str | None = None,
             n_slots: int = 4, cache_len: int = 32) -> dict:
    """The probe as a library call (tests use this). Returns a dict:
    ``op_delta`` (op -> sharded_count - unsharded_count, zero-delta ops
    omitted), ``n_changed_lines`` (normalized diff size), ``files``
    (paths written when ``out_dir`` is given)."""
    n_dev = mesh_shape[0] * mesh_shape[1]
    if len(jax.devices()) < n_dev:
        raise RuntimeError(
            f"need {n_dev} devices for mesh {mesh_shape}; run with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    cfg = make_cfg(mixer)
    mesh = Mesh(np.asarray(jax.devices()[:n_dev]).reshape(mesh_shape),
                ("data", "model"))
    ref = hlo_texts(lower_decode_step(cfg, None, n_slots=n_slots,
                                      cache_len=cache_len), stage)
    shr = hlo_texts(lower_decode_step(cfg, mesh, n_slots=n_slots,
                                      cache_len=cache_len), stage)
    h_ref = op_histogram(ref, stage)
    h_shr = op_histogram(shr, stage)
    delta = {op: h_shr.get(op, 0) - h_ref.get(op, 0)
             for op in sorted(set(h_ref) | set(h_shr))
             if h_shr.get(op, 0) != h_ref.get(op, 0)}
    n_ref, n_shr = normalize(ref), normalize(shr)
    changed = sum(1 for ln in difflib.unified_diff(n_ref, n_shr, n=0)
                  if ln[:1] in "+-" and ln[:3] not in ("+++", "---"))
    files = []
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{mixer}_{mesh_shape[0]}x{mesh_shape[1]}_{stage}"
        for name, text in ((f"decode_unsharded_{tag}.txt", ref),
                           (f"decode_sharded_{tag}.txt", shr)):
            path = os.path.join(out_dir, name)
            with open(path, "w") as f:
                f.write(text)
            files.append(path)
    return {"mixer": mixer, "mesh": mesh_shape, "stage": stage,
            "op_delta": delta, "n_changed_lines": changed,
            "ops_unsharded": sum(h_ref.values()),
            "ops_sharded": sum(h_shr.values()), "files": files}


def main() -> int:
    ap = argparse.ArgumentParser(
        description="structural HLO diff, sharded vs unsharded decode")
    ap.add_argument("--mixer", default="hybrid", choices=sorted(CONFIGS))
    ap.add_argument("--mesh", default="2x4",
                    help="data x model, e.g. 2x4 or 1x8")
    ap.add_argument("--stage", default="opt",
                    choices=("stablehlo", "opt"),
                    help="stablehlo = pre-partitioning (annotations); "
                         "opt = post-SPMD optimized HLO (what runs)")
    ap.add_argument("--out", default="/tmp/hlo_diff",
                    help="directory for the full dumped programs")
    ap.add_argument("--full-diff", action="store_true",
                    help="print the normalized unified diff, not just "
                         "the histogram")
    args = ap.parse_args()
    mesh_shape = tuple(int(x) for x in args.mesh.split("x"))

    res = hlo_diff(args.mixer, mesh_shape, stage=args.stage,
                   out_dir=args.out)
    print(f"decode step: {args.mixer} on {args.mesh} ({args.stage})")
    print(f"  ops: {res['ops_unsharded']} unsharded -> "
          f"{res['ops_sharded']} sharded; "
          f"{res['n_changed_lines']} normalized lines differ")
    print("  op histogram delta (sharded - unsharded):")
    for op, d in sorted(res["op_delta"].items(), key=lambda kv: -abs(kv[1])):
        print(f"    {op:<32} {d:+d}")
    for path in res["files"]:
        print(f"  wrote {path}")
    if args.full_diff:
        cfg = make_cfg(args.mixer)
        mesh = Mesh(np.asarray(
            jax.devices()[:mesh_shape[0] * mesh_shape[1]]
        ).reshape(mesh_shape), ("data", "model"))
        ref = normalize(hlo_texts(lower_decode_step(cfg), args.stage))
        shr = normalize(hlo_texts(lower_decode_step(cfg, mesh),
                                  args.stage))
        sys.stdout.writelines(
            ln + "\n" for ln in difflib.unified_diff(
                ref, shr, "unsharded", "sharded", lineterm=""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
