"""Docs lane: executable documentation + link integrity.

Two checks over README.md and docs/*.md:

1. **Links** — every relative (intra-repo) markdown link target must
   exist, anchors stripped. External links (http/https/mailto) are not
   touched (CI must not flake on the network).
2. **Snippets** — every fenced ```python block is executed, blocks of
   one file sharing a namespace in order (so a later block can use a
   result the previous one bound). A small prelude provides the names
   the docs assume (``params``, ``cfg``, ``requests``, ...) over a tiny
   model, so the snippets run in seconds on CPU while staying the
   EXACT code a reader would copy. A snippet that raises fails the
   lane — documentation that stops compiling stops merging.

Run:  PYTHONPATH=src python tools/check_docs.py
(CI sets XLA_FLAGS=--xla_force_host_platform_device_count=8 so the
mesh-flavored snippets could shard; locally they run single-device.)
"""
from __future__ import annotations

import os
import re
import sys
import textwrap
import traceback

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# [text](target) — excluding images; target captured up to ) or space
_LINK = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
_FENCE = re.compile(r"^```(\w*)\s*$")

# the namespace documentation snippets are written against: a tiny
# attention LM + a few mixed-length requests
PRELUDE = """
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, init_params
from repro.serve import (EngineConfig, Request, generate, serve_continuous,
                         serve_disaggregated)

cfg = ModelConfig(name="docs", mixer="attn", ffn="swiglu", n_layers=2,
                  d_model=32, n_heads=2, n_kv=2, head_dim=16, d_ff=64,
                  vocab=64, dtype="float32", logit_chunk=16, remat=False)
params = init_params(jax.random.PRNGKey(0), cfg)
_rng = np.random.default_rng(42)
requests = [
    Request(rid=i, tokens=_rng.integers(0, cfg.vocab, size=6 + 3 * i),
            max_new_tokens=4, arrival=0)
    for i in range(3)
]
reqs = requests
mesh = None
"""


def doc_files() -> list[str]:
    out = [os.path.join(ROOT, "README.md")]
    docs = os.path.join(ROOT, "docs")
    if os.path.isdir(docs):
        out += sorted(os.path.join(docs, f) for f in os.listdir(docs)
                      if f.endswith(".md"))
    return out


def check_links(path: str) -> list[str]:
    errors = []
    with open(path) as f:
        text = f.read()
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = os.path.normpath(
            os.path.join(os.path.dirname(path), rel))
        if not os.path.exists(resolved):
            errors.append(f"{os.path.relpath(path, ROOT)}: dangling link "
                          f"-> {target}")
    return errors


def python_blocks(path: str) -> list[tuple[int, str]]:
    """(first_line_number, source) for every ```python fence."""
    blocks, cur, lang, start = [], None, None, 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            fence = _FENCE.match(line.strip())
            if fence and cur is None:
                lang, cur, start = fence.group(1), [], lineno + 1
            elif line.strip() == "```" and cur is not None:
                if lang == "python":
                    # blocks nested in list items ride indented
                    blocks.append((start, textwrap.dedent("".join(cur))))
                cur = None
            elif cur is not None:
                cur.append(line)
    return blocks


def run_snippets(path: str) -> list[str]:
    blocks = python_blocks(path)
    if not blocks:
        return []
    rel = os.path.relpath(path, ROOT)
    ns: dict = {}
    try:
        exec(compile(PRELUDE, "<docs prelude>", "exec"), ns)
    except Exception:
        traceback.print_exc()
        return [f"{rel}: docs prelude failed (see traceback)"]
    errors = []
    for start, src in blocks:
        try:
            exec(compile(src, f"{rel}:{start}", "exec"), ns)
            print(f"  ok  {rel}:{start} ({len(src.splitlines())} lines)")
        except Exception:
            traceback.print_exc()
            errors.append(f"{rel}:{start}: snippet raised (see traceback)")
    return errors


def main() -> int:
    errors = []
    files = doc_files()
    print(f"docs lane: {len(files)} files")
    for path in files:
        errors += check_links(path)
    for path in files:
        errors += run_snippets(path)
    if errors:
        print(f"\nDOCS CHECK FAILED ({len(errors)} error(s)):")
        for e in errors:
            print(f"  {e}")
        return 1
    print("\ndocs check passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
