#!/usr/bin/env python
"""Latency breakdown from a Chrome-trace JSON file.

Reads a trace written by ``repro.obs.trace`` (or any conforming
``trace_event`` JSON), prints a per-span-name table (count, total,
mean, exact p50/p95/p99, max — sorted by total time) and, when the
trace holds a serve run, the request-lifecycle table (queue wait ->
prefill -> TTFT -> per-request decode).

Usage:
  PYTHONPATH=src python tools/trace_summary.py trace.json
  PYTHONPATH=src python tools/trace_summary.py trace.json --json

The heavy lifting lives in :mod:`repro.obs.summary` so tests and docs
snippets can call it in-process; this file is the CLI.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.obs.summary import (  # noqa: E402
    load_trace, report, request_table, summarize,
)


def main() -> int:
    ap = argparse.ArgumentParser(
        description="latency breakdown from a Chrome-trace JSON")
    ap.add_argument("trace", help="path to the exported trace file")
    ap.add_argument("--json", action="store_true",
                    help="emit the summary rows as JSON instead of a "
                         "formatted table")
    args = ap.parse_args()
    if not os.path.exists(args.trace):
        print(f"no such trace file: {args.trace}", file=sys.stderr)
        return 1
    if args.json:
        events = load_trace(args.trace)
        print(json.dumps({"spans": summarize(events),
                          "request_lifecycle": request_table(events)},
                         indent=2))
        return 0
    print(report(args.trace))
    return 0


if __name__ == "__main__":
    sys.exit(main())
