"""Architecture registry: ``--arch <id>`` resolution + per-cell policy."""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import SHAPES, SUBQUADRATIC, ModelConfig, reduced
from repro.dist.rules import ShardingPolicy

_MODULES = {
    "mamba2-370m": "mamba2_370m",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "musicgen-medium": "musicgen_medium",
    "internlm2-20b": "internlm2_20b",
    "qwen3-32b": "qwen3_32b",
    "llama3-405b": "llama3_405b",
    "gemma-2b": "gemma_2b",
    "internvl2-2b": "internvl2_2b",
    "hymba-1.5b": "hymba_1p5b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_reduced(arch: str, **overrides) -> ModelConfig:
    return reduced(get_config(arch), **overrides)


def cell_is_runnable(arch: str, shape: str) -> bool:
    """long_500k only runs on sub-quadratic mixers (DESIGN.md §4)."""
    cfg = get_config(arch)
    if shape == "long_500k":
        return cfg.mixer in SUBQUADRATIC
    return True


def shape_overrides(arch: str, shape: str) -> dict:
    """Config adjustments a given cell needs (e.g. hymba long-context
    window; moe dispatch chunk tuning for the huge-token cells)."""
    over: dict = {}
    cfg = get_config(arch)
    if shape == "long_500k" and cfg.mixer == "hybrid":
        over["window"] = 2048
    if shape == "train_4k" and cfg.ffn == "moe":
        over["moe_chunk"] = 4096
    return over


def sharding_policy(arch: str, shape: str) -> ShardingPolicy:
    """Per-cell distribution policy (DESIGN.md §5)."""
    cfg = get_config(arch)
    big = cfg.param_count() > 3e10          # 30B+ -> FSDP weights
    # SP (shard saved residuals over model) saves memory but conflicts
    # with MoE token grouping: regrouping seq-sharded tokens cost 2.9 TB
    # of collective-permute per step on deepseek-v2 (§Perf iter 2) — MoE
    # archs run without SP (their d_model keeps residuals affordable).
    seq = shape == "train_4k" and cfg.ffn != "moe"
    return ShardingPolicy(fsdp=big, seq_shard=seq, shard_cache_seq=True)


def train_microbatches(arch: str) -> int:
    """Grad-accumulation depth (capped to per-dp-shard batch by the
    launcher). Keeps saved activations within HBM (EXPERIMENTS.md §Dry-run
    memory study)."""
    cfg = get_config(arch)
    if cfg.param_count() > 1e11:
        return 16
    if cfg.param_count() > 1e10:
        return 8
    return 4


def all_cells() -> list[tuple[str, str]]:
    """The 40 assigned (arch x shape) cells, including recorded skips."""
    return [(a, s) for a in ARCH_IDS for s in SHAPES]
