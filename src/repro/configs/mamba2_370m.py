"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.
48L d_model=1024 d_ff=0 vocab=50280 ssm_state=128. [arXiv:2405.21060]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    mixer="ssd",
    ffn="none",
    n_layers=48,
    d_model=1024,
    n_heads=32,            # SSD heads = d_inner / headdim = 2048/64
    n_kv=32,
    d_ff=0,
    vocab=50280,
    d_state=128,
    ssd_expand=2,
    ssd_headdim=64,
    ssd_chunk=256,
    conv_k=4,
    vocab_pad=256,
    ssd_state_dtype="bfloat16",  # halves decode state traffic (§Perf)
)
