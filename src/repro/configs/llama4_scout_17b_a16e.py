"""llama4-scout-17b-a16e [moe] — MoE 16e top-1 + shared expert, early fusion.
48L d_model=5120 40H (GQA kv=8) expert d_ff=8192 vocab=202048.
[hf:meta-llama/Llama-4-Scout-17B-16E]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    mixer="attn",
    ffn="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    n_experts=16,
    top_k=1,
    n_shared=1,
    moe_dff=8192,
    capacity_factor=1.25,
    moe_chunk=4096,
)
