"""llama3-405b [dense] — GQA kv=8, 128k vocab. The largest assigned cell.
126L d_model=16384 128H d_ff=53248 vocab=128256. [arXiv:2407.21783]
long_500k is SKIPPED (pure quadratic attention; see DESIGN.md §4).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    mixer="attn",
    ffn="swiglu",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv=8,
    head_dim=128,
    d_ff=53248,
    vocab=128256,
)
