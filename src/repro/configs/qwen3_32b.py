"""qwen3-32b [dense] — qk_norm, GQA kv=8.
64L d_model=5120 64H d_ff=25600 vocab=151936. [hf:Qwen/Qwen3-32B]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    mixer="attn",
    ffn="swiglu",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv=8,
    head_dim=128,
    d_ff=25600,
    vocab=151936,
    qk_norm=True,
)
