"""internvl2-2b [vlm] — InternViT frontend (STUB: precomputed patch
embeddings) + InternLM2-2B backbone.
24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553. [arXiv:2404.16821]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    mixer="attn",
    ffn="swiglu",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv=8,
    head_dim=128,
    d_ff=8192,
    vocab=92553,
    n_img_tokens=256,
    vocab_pad=256,
)
