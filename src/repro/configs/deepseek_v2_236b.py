"""deepseek-v2-236b [moe] — MLA (kv_lora=512), 2 shared + 160 routed top-6.
60L d_model=5120 128H moe_dff=1536 vocab=102400. [arXiv:2405.04434]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    mixer="mla",
    ffn="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv=128,
    head_dim=128,
    d_ff=1536,
    vocab=102400,
    kv_lora=512,
    q_lora=1536,
    rope_head_dim=64,
    n_experts=160,
    top_k=6,
    n_shared=2,
    moe_dff=1536,
    capacity_factor=1.25,
    moe_chunk=4096,
)
