"""gemma-2b [dense] — GeGLU, head_dim=256, MQA (kv=1).
18L d_model=2048 8H d_ff=16384 vocab=256000. [arXiv:2403.08295]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    mixer="attn",
    ffn="geglu",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv=1,
    head_dim=256,
    d_ff=16384,
    vocab=256000,
)
