"""hymba-1.5b [hybrid] — parallel attention + mamba heads in each layer.
32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001 ssm_state=16.
[arXiv:2411.13676]
long_500k RUNS with sliding-window attention (2048) on the attn path —
Hymba's global/local pattern — while the SSD path carries long context.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    mixer="hybrid",
    ffn="swiglu",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    d_state=16,
    ssd_expand=1,          # d_inner = d_model = 1600; 25 SSD heads of 64
    ssd_headdim=64,
    ssd_chunk=256,
    conv_k=4,
    ssd_split_proj=True,   # 2*di+2*n+h = 3257 is mesh-indivisible
    vocab_pad=256,
    ssd_state_dtype="bfloat16",  # halves decode state traffic (§Perf)
)
