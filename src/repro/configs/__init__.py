"""repro.configs — assigned architectures + the paper's Table-1 models."""
from .registry import (
    ARCH_IDS,
    all_cells,
    cell_is_runnable,
    get_config,
    get_reduced,
    shape_overrides,
    sharding_policy,
    train_microbatches,
)
from .paper_models import PAPER_MODELS, PaperModel, RNNLayerCfg

__all__ = [
    "ARCH_IDS", "all_cells", "cell_is_runnable", "get_config",
    "get_reduced", "shape_overrides", "sharding_policy",
    "train_microbatches", "PAPER_MODELS", "PaperModel", "RNNLayerCfg",
]
