"""The paper's own benchmark models (Table 1) — 10 RNN apps, 20 layers.

Dims are exactly Table 1's; datasets are synthetic stand-ins (offline
container, see repro.data). ``nonstructured_pr`` is the paper-reported
non-structured pruning rate (the theoretical optimum CSB approaches).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class RNNLayerCfg:
    idx: int
    cell: str           # lstm | gru | lstmp | ligru
    n_input: int
    n_hidden: int
    proj: int | None = None


@dataclasses.dataclass(frozen=True)
class PaperModel:
    abbr: str
    app: str
    dataset: str
    metric: str
    higher_is_better: bool
    layers: tuple[RNNLayerCfg, ...]
    nonstructured_pr: float   # paper Table 1 (x compression)


PAPER_MODELS: dict[str, PaperModel] = {
    "MT1": PaperModel(
        "MT1", "Machine Translation", "PTB", "PPL", False,
        (RNNLayerCfg(1, "lstm", 128, 256), RNNLayerCfg(2, "lstm", 256, 256)),
        13.2),
    "MT2": PaperModel(
        "MT2", "Machine Translation", "PTB", "PPL", False,
        (RNNLayerCfg(3, "lstm", 1500, 1500),
         RNNLayerCfg(4, "lstm", 1500, 1500)),
        16.3),
    "SR1": PaperModel(
        "SR1", "Speech Recognition", "TIMIT", "PER", False,
        (RNNLayerCfg(5, "lstmp", 153, 1024, proj=512),
         RNNLayerCfg(6, "lstmp", 512, 1024, proj=512)),
        14.5),
    "SR2": PaperModel(
        "SR2", "Speech Recognition", "TIMIT", "PER", False,
        (RNNLayerCfg(7, "gru", 39, 1024), RNNLayerCfg(8, "gru", 1024, 1024)),
        21.7),
    "SR3": PaperModel(
        "SR3", "Speech Recognition", "TIMIT", "PER", False,
        (RNNLayerCfg(9, "ligru", 39, 512), RNNLayerCfg(10, "ligru", 512, 512)),
        7.1),
    "SR4": PaperModel(
        "SR4", "Speech Recognition", "TDIGIT", "Accuracy", True,
        (RNNLayerCfg(11, "gru", 39, 256),),
        25.7),
    "SPP": PaperModel(
        "SPP", "Stock Price Prediction", "S&P500", "NPD", False,
        (RNNLayerCfg(12, "lstm", 1, 128), RNNLayerCfg(13, "lstm", 128, 128)),
        4.1),
    "SC1": PaperModel(
        "SC1", "Sentiment Classification", "IMDB", "Accuracy", True,
        (RNNLayerCfg(14, "lstm", 32, 512), RNNLayerCfg(15, "lstm", 512, 512),
         RNNLayerCfg(16, "lstm", 512, 512)),
        10.4),
    "SC2": PaperModel(
        "SC2", "Sentiment Classification", "MR", "Accuracy", True,
        (RNNLayerCfg(17, "lstm", 50, 256),),
        7.2),
    "QA": PaperModel(
        "QA", "Question Answering", "BABI", "Accuracy", True,
        (RNNLayerCfg(18, "lstm", 50, 256), RNNLayerCfg(19, "lstm", 256, 256),
         RNNLayerCfg(20, "lstm", 256, 256)),
        7.9),
}
