"""musicgen-medium [audio] — decoder-only over EnCodec tokens (4 codebooks).
48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048. [arXiv:2306.05284]
The EnCodec frontend is a stub: inputs are codebook token ids.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    mixer="attn",
    ffn="swiglu",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv=24,
    d_ff=6144,
    vocab=2048,
    n_codebooks=4,
)
