"""internlm2-20b [dense] — GQA kv=8.
48L d_model=6144 48H d_ff=16384 vocab=92544. [arXiv:2403.17297]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    mixer="attn",
    ffn="swiglu",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv=8,
    head_dim=128,
    d_ff=16384,
    vocab=92544,
)
