"""Low-overhead span tracer with a Chrome ``trace_event`` exporter.

The serve/train stacks are instrumented with *spans* (begin/end pairs),
*instants* (point events) and externally-timed *complete* events, all
written into a **preallocated ring buffer** — recording is an index
bump plus a tuple store, never a list growth, so a multi-minute serve
run traces at a bounded memory footprint (the oldest events fall off;
``dropped`` counts them).

Tracing is **off by default** and the disabled path is a no-op fast
path: module-level helpers read one global, compare against ``None``
and return a shared singleton — no dict, no tuple, no timestamps
(``tests/test_obs.py`` asserts the disabled hot path is
allocation-free). Instrumented code therefore stays on the gated perf
paths (``serve/*/us_per_token``) without moving them.

Export targets the Chrome ``trace_event`` JSON format (the
``traceEvents`` array of ``ph``/``ts``/``pid``/``tid``/``name``
objects), so a trace written by :func:`export_chrome` loads directly in
Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``. Spans from
different logical *tracks* (the engine loop, each request's lifecycle)
render as separate named rows via ``thread_name`` metadata events.

Usage::

    from repro.obs import trace
    trace.enable()                       # returns the live Tracer
    with trace.span("serve/decode_step"):
        ...
    trace.instant("sched/page_stall", args={"rid": 3})
    trace.export_chrome("trace.json")    # -> Perfetto
    trace.disable()

``tools/trace_summary.py`` prints latency breakdowns (exact
percentiles per span name, request-lifecycle table) from the exported
file.
"""
from __future__ import annotations

import json
import os
import threading
import time


class _NullSpan:
    """Shared do-nothing context manager the disabled paths hand out."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """Context-manager handle pairing one ``begin`` with its ``end``."""

    __slots__ = ("_tracer", "_name", "_track", "_args", "_t0")

    def __init__(self, tracer, name, track, args):
        self._tracer = tracer
        self._name = name
        self._track = track
        self._args = args

    def __enter__(self):
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        self._tracer._record("X", self._name, self._t0, t1 - self._t0,
                             self._track, self._args)
        return False


class Tracer:
    """Ring-buffered event store (see module docstring).

    ``capacity`` bounds the live event count; recording past it
    overwrites the oldest events and bumps :attr:`dropped`.
    """

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        # preallocated ring: slot i % capacity holds event i
        self._ring: list = [None] * capacity
        self._n = 0                     # events ever recorded
        self._lock = threading.Lock()
        self._stacks = threading.local()
        self._t0 = time.perf_counter_ns()

    # -- recording -----------------------------------------------------------
    def _record(self, ph, name, ts_ns, dur_ns, track, args) -> None:
        tid = (track if track is not None
               else f"thread-{threading.get_ident() & 0xffff}")
        with self._lock:
            self._ring[self._n % self.capacity] = (
                ph, name, ts_ns, dur_ns, tid, args)
            self._n += 1

    def span(self, name: str, track: str | None = None,
             args: dict | None = None) -> _Span:
        """Context manager timing its ``with`` body as one X event."""
        return _Span(self, name, track, args)

    def begin(self, name: str, track: str | None = None,
              args: dict | None = None) -> None:
        """Open a nested span on this thread (pair with :meth:`end`)."""
        stack = getattr(self._stacks, "open", None)
        if stack is None:
            stack = self._stacks.open = []
        stack.append((name, track, args, time.perf_counter_ns()))

    def end(self, args: dict | None = None) -> None:
        """Close the innermost :meth:`begin` span; ``args`` merge over
        the ones passed to ``begin``."""
        t1 = time.perf_counter_ns()
        name, track, a0, t0 = self._stacks.open.pop()
        if args:
            a0 = {**(a0 or {}), **args}
        self._record("X", name, t0, t1 - t0, track, a0)

    def instant(self, name: str, track: str | None = None,
                args: dict | None = None) -> None:
        self._record("i", name, time.perf_counter_ns(), 0, track, args)

    def complete(self, name: str, t0_ns: int, dur_ns: int,
                 track: str | None = None, args: dict | None = None) -> None:
        """Record an externally-timed span (timestamps from
        :meth:`now_ns`) — zero timing overhead at the measured site."""
        self._record("X", name, t0_ns, dur_ns, track, args)

    def now_ns(self) -> int:
        """Clock for :meth:`complete` (``time.perf_counter_ns``)."""
        return time.perf_counter_ns()

    # -- introspection / export ----------------------------------------------
    @property
    def dropped(self) -> int:
        """Events overwritten by ring wraparound."""
        return max(self._n - self.capacity, 0)

    def events(self) -> list[tuple]:
        """Live events, oldest first (raw internal tuples)."""
        with self._lock:
            n, cap = self._n, self.capacity
            if n <= cap:
                return [e for e in self._ring[:n]]
            head = n % cap
            return self._ring[head:] + self._ring[:head]

    def to_chrome(self) -> dict:
        """Chrome ``trace_event`` JSON object (``traceEvents`` array).

        Timestamps are microseconds relative to tracer start; every
        event carries the required ``ph``/``ts``/``pid``/``tid``/
        ``name`` fields, and each distinct track gets a ``thread_name``
        metadata event so Perfetto labels the rows.
        """
        pid = os.getpid()
        tids: dict[str, int] = {}
        out = []
        for ph, name, ts_ns, dur_ns, track, args in self.events():
            tid = tids.setdefault(track, len(tids) + 1)
            ev = {
                "ph": ph,
                "name": name,
                "ts": (ts_ns - self._t0) / 1e3,
                "pid": pid,
                "tid": tid,
                "cat": "repro",
            }
            if ph == "X":
                ev["dur"] = dur_ns / 1e3
            if ph == "i":
                ev["s"] = "t"           # instant scope: thread
            if args:
                ev["args"] = dict(args)
            out.append(ev)
        meta = [
            {"ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
             "ts": 0, "args": {"name": track}}
            for track, tid in tids.items()
        ]
        return {"traceEvents": meta + out,
                "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def export_chrome(self, path: str) -> str:
        """Write :meth:`to_chrome` as JSON; returns ``path``."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


# ---------------------------------------------------------------------------
# process-global tracer: module functions are the instrumentation API
# ---------------------------------------------------------------------------

_tracer: Tracer | None = None           # None <=> tracing disabled


def enable(capacity: int = 65536) -> Tracer:
    """Install a fresh process-global tracer and return it."""
    global _tracer
    _tracer = Tracer(capacity)
    return _tracer


def disable() -> Tracer | None:
    """Stop tracing; returns the tracer that was live (export still
    works on it) or None."""
    global _tracer
    t, _tracer = _tracer, None
    return t


def enabled() -> bool:
    return _tracer is not None


def get() -> Tracer | None:
    """The live tracer, or None when disabled. Hot loops fetch this
    once and branch on ``is not None`` — the cheapest gate."""
    return _tracer


def span(name, track=None, args=None):
    t = _tracer
    if t is None:
        return _NULL_SPAN
    return t.span(name, track, args)


def instant(name, track=None, args=None):
    t = _tracer
    if t is None:
        return
    t.instant(name, track, args)


def export_chrome(path: str) -> str | None:
    """Export the live tracer's events; None when tracing is off."""
    t = _tracer
    if t is None:
        return None
    return t.export_chrome(path)


__all__ = ["Tracer", "enable", "disable", "enabled", "get", "span",
           "instant", "export_chrome"]
