"""repro.obs — request-lifecycle tracing + metrics for the serve stack.

Two independent, process-global, **off-by-default** facilities:

* :mod:`repro.obs.trace`   — ring-buffered span tracer with a Chrome
  ``trace_event`` exporter (Perfetto / ``chrome://tracing``).
* :mod:`repro.obs.metrics` — counters, gauge timelines and
  exact-percentile histograms, exportable to a plain dict/JSON.

The serve engine, scheduler, page pool, frame server, train loop and
CSB partitioner are pre-instrumented; enabling either facility makes
them emit (disabled, the instrumentation is a single global read —
see each module's docstring). ``tools/trace_summary.py`` turns an
exported trace into latency-breakdown tables;
:mod:`repro.obs.summary` is its importable half.

    from repro.obs import enable_all, disable_all, trace, metrics
    enable_all()
    ... serve / train ...
    trace.export_chrome("trace.json")
    print(metrics.registry().histogram("serve/req/ttft_us").summary())
    disable_all()
"""
from . import metrics, summary, trace


def enable_all(trace_capacity: int = 65536):
    """Enable tracing AND metrics; returns (tracer, registry)."""
    return trace.enable(trace_capacity), metrics.enable()


def disable_all():
    """Disable both; returns (tracer, registry) that were live."""
    return trace.disable(), metrics.disable()


__all__ = ["trace", "metrics", "summary", "enable_all", "disable_all"]
