"""Trace post-processing: latency breakdowns from a Chrome-trace file.

Library half of ``tools/trace_summary.py`` (importable so the docs
snippets and tests run it in-process). Works on any file
:func:`repro.obs.trace.Tracer.export_chrome` wrote — and on any
conforming ``trace_event`` JSON: only ``ph``/``name``/``ts``/``dur``
are read.

Two views:

* :func:`summarize` — one row per span *name*: count, total wall time
  and exact nearest-rank percentiles over the span durations. Sorted by
  total time, this is the "where do the microseconds go" table.
* :func:`request_table` — the serve request lifecycle: rows for the
  ``serve/req/*`` spans the engine emits (queue wait, prefill, TTFT,
  decode), i.e. per-request latency distributions rather than
  per-span-site ones.
"""
from __future__ import annotations

import json
import math


def load_trace(path: str) -> list[dict]:
    """Events from a Chrome-trace JSON file (object with
    ``traceEvents`` or a bare event array)."""
    with open(path) as f:
        obj = json.load(f)
    return obj["traceEvents"] if isinstance(obj, dict) else obj


def _pct(sorted_vals: list[float], q: float) -> float:
    rank = max(math.ceil(q / 100.0 * len(sorted_vals)), 1)
    return sorted_vals[min(rank, len(sorted_vals)) - 1]


def summarize(events: list[dict]) -> list[dict]:
    """Per-name duration stats over the X (complete) events, sorted by
    total time descending. Durations are Chrome-trace microseconds."""
    by_name: dict[str, list[float]] = {}
    for ev in events:
        if ev.get("ph") == "X" and "dur" in ev:
            by_name.setdefault(ev["name"], []).append(float(ev["dur"]))
    rows = []
    for name, durs in by_name.items():
        durs.sort()
        total = sum(durs)
        rows.append({
            "name": name,
            "count": len(durs),
            "total_us": total,
            "mean_us": total / len(durs),
            "p50_us": _pct(durs, 50),
            "p95_us": _pct(durs, 95),
            "p99_us": _pct(durs, 99),
            "max_us": durs[-1],
        })
    rows.sort(key=lambda r: -r["total_us"])
    return rows


# the engine's per-request lifecycle spans, in pipeline order
REQUEST_SPANS = ("serve/req/queue_wait", "serve/req/prefill",
                 "serve/req/ttft", "serve/req/decode")


def request_table(events: list[dict]) -> list[dict]:
    """The :func:`summarize` rows restricted to the request-lifecycle
    spans, in lifecycle order (queue wait -> prefill -> TTFT ->
    decode). Empty when the trace has no serve run in it."""
    rows = {r["name"]: r for r in summarize(events)}
    return [rows[n] for n in REQUEST_SPANS if n in rows]


def format_table(rows: list[dict], title: str = "span") -> str:
    """Fixed-width text table for terminal output."""
    if not rows:
        return "(no complete events)"
    w = max(len(title), max(len(r["name"]) for r in rows))
    hdr = (f"{title:<{w}}  {'count':>6}  {'total_ms':>9}  {'mean_us':>9}"
           f"  {'p50_us':>9}  {'p95_us':>9}  {'p99_us':>9}  {'max_us':>9}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['name']:<{w}}  {r['count']:>6}"
            f"  {r['total_us'] / 1e3:>9.2f}  {r['mean_us']:>9.1f}"
            f"  {r['p50_us']:>9.1f}  {r['p95_us']:>9.1f}"
            f"  {r['p99_us']:>9.1f}  {r['max_us']:>9.1f}")
    return "\n".join(lines)


def report(path: str) -> str:
    """The full trace_summary CLI output for one trace file."""
    events = load_trace(path)
    parts = [f"trace: {path} ({len(events)} events)", "",
             format_table(summarize(events))]
    req = request_table(events)
    if req:
        parts += ["", "request lifecycle (per-request distributions):",
                  format_table(req, title="stage")]
    return "\n".join(parts)


__all__ = ["load_trace", "summarize", "request_table", "format_table",
           "report", "REQUEST_SPANS"]
