"""Metrics registry: counters, gauges (timelines) and exact-percentile
histograms, exportable to a plain dict/JSON.

Complements ``obs.trace``: the tracer answers *where did this request's
microseconds go*, the registry answers *what were the distributions and
running totals* — TTFT/queue-wait percentiles, pool occupancy over
time, admission outcomes. Like the tracer it is **off by default**:
instrumented code does ``reg = metrics.get()`` and skips recording when
that returns None, so the disabled hot path is one global read.

Naming scheme (used by every instrumented subsystem; see
docs/observability.md):

    <subsystem>/<object>/<metric>[_<unit>]

e.g. ``serve/req/ttft_us`` (histogram), ``serve/pool/pages`` (gauge
timeline, one sample per decode step), ``serve/sched/page_stalls``
(counter), ``train/step/wall_us`` (histogram),
``dist/csb_partition/imbalance`` (gauge).

Percentiles are **exact** — histograms keep raw samples (bounded by
``max_samples``, reservoir-free: the cap is far above any serve run
this repo times) and quantiles use the nearest-rank method, so p50 of
[1, 2] is 1.0, not an interpolation artifact, and tiny sample counts
(0, 1, 2 — the edge cases tests pin) behave predictably.
"""
from __future__ import annotations

import dataclasses
import json
import math


@dataclasses.dataclass
class Counter:
    """Monotonic event count."""

    value: float = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-value metric that also keeps its set() history — the
    timeline view (pool occupancy per decode step) the final-summary
    stats can't give."""

    __slots__ = ("last", "series", "max_series", "dropped")

    def __init__(self, max_series: int = 65536):
        self.last: float | None = None
        self.series: list[float] = []
        self.max_series = max_series
        self.dropped = 0

    def set(self, v: float) -> None:
        v = float(v)
        self.last = v
        if len(self.series) < self.max_series:
            self.series.append(v)
        else:
            self.dropped += 1


class Histogram:
    """Raw-sample histogram with exact nearest-rank percentiles."""

    __slots__ = ("samples", "max_samples", "dropped", "_sum")

    def __init__(self, max_samples: int = 262144):
        self.samples: list[float] = []
        self.max_samples = max_samples
        self.dropped = 0
        self._sum = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self._sum += v
        if len(self.samples) < self.max_samples:
            self.samples.append(v)
        else:
            self.dropped += 1

    @property
    def count(self) -> int:
        return len(self.samples) + self.dropped

    def percentile(self, q: float) -> float | None:
        """Exact nearest-rank percentile: the ceil(q/100 * n)-th
        smallest sample. None when empty."""
        s = sorted(self.samples)
        if not s:
            return None
        rank = max(math.ceil(q / 100.0 * len(s)), 1)
        return s[min(rank, len(s)) - 1]

    def summary(self) -> dict:
        n = len(self.samples)
        if n == 0:
            return {"count": self.count, "min": None, "max": None,
                    "mean": None, "p50": None, "p95": None, "p99": None}
        return {
            "count": self.count,
            "min": min(self.samples),
            "max": max(self.samples),
            "mean": self._sum / self.count,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
        }


class MetricsRegistry:
    """Get-or-create store for the three metric kinds; a name is bound
    to one kind for the registry's lifetime (mixing raises)."""

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def _claim(self, name: str, own: dict) -> None:
        for kind in (self._counters, self._gauges, self._histograms):
            if kind is not own and name in kind:
                raise ValueError(
                    f"metric {name!r} already registered as another kind")

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            self._claim(name, self._counters)
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            self._claim(name, self._gauges)
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            self._claim(name, self._histograms)
            h = self._histograms[name] = Histogram()
        return h

    def to_dict(self, series: bool = True) -> dict:
        """Plain-dict export (JSON-serializable). ``series=False``
        drops gauge timelines (summary-only view)."""
        return {
            "counters": {k: c.value for k, c in self._counters.items()},
            "gauges": {
                k: ({"last": g.last, "n": len(g.series) + g.dropped,
                     "series": list(g.series)} if series
                    else {"last": g.last, "n": len(g.series) + g.dropped})
                for k, g in self._gauges.items()},
            "histograms": {k: h.summary()
                           for k, h in self._histograms.items()},
        }

    def to_json(self, series: bool = True) -> str:
        return json.dumps(self.to_dict(series=series))


# ---------------------------------------------------------------------------
# process-global registry, off by default (mirrors obs.trace)
# ---------------------------------------------------------------------------

_registry: MetricsRegistry | None = None


def enable() -> MetricsRegistry:
    """Install a fresh process-global registry and return it."""
    global _registry
    _registry = MetricsRegistry()
    return _registry


def disable() -> MetricsRegistry | None:
    global _registry
    r, _registry = _registry, None
    return r


def enabled() -> bool:
    return _registry is not None


def get() -> MetricsRegistry | None:
    """The live registry, or None when metrics are off. Instrumented
    code branches on ``is not None`` — the disabled fast path."""
    return _registry


def registry() -> MetricsRegistry:
    """The live registry, enabling on first use (for interactive /
    docs flows; instrumentation uses :func:`get` and never
    auto-enables)."""
    global _registry
    if _registry is None:
        _registry = MetricsRegistry()
    return _registry


__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "enable", "disable", "enabled", "get", "registry"]
