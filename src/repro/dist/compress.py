"""int8 error-feedback gradient compression.

Distributed data parallelism all-reduces full-precision gradients every
step; at pod scale that traffic competes with the model collectives. The
classic fix (1-bit SGD / EF-SGD lineage) is to quantize the gradient and
*carry the quantization error forward*: what round-off drops this step
is added back into the next step's gradient, so the sum of transmitted
gradients tracks the sum of true gradients and SGD still converges.

Per leaf: ``scale = max|g + residual| / 127``, values round to int8 on
that grid, and ``residual`` keeps the difference. All ops are pure
jax.numpy so the compressor composes with jit/grad and shards like any
other tree op.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class Compressed(NamedTuple):
    """One quantized leaf: int8 codes + the fp32 grid scale."""

    q: jax.Array       # int8, same shape as the source leaf
    scale: jax.Array   # fp32 scalar


def _is_comp(x) -> bool:
    return isinstance(x, Compressed)


def compress_init(tree: PyTree) -> PyTree:
    """Zero error-feedback residuals shaped like the gradient tree."""
    return jax.tree.map(
        lambda g: jnp.zeros(jnp.shape(g), jnp.float32), tree)


def _compress_leaf(g: jax.Array, r: jax.Array):
    e = jnp.asarray(g, jnp.float32) + r
    scale = jnp.max(jnp.abs(e)) / 127.0
    q = jnp.clip(jnp.round(e / jnp.maximum(scale, 1e-30)), -127, 127)
    q = q.astype(jnp.int8)
    sent = q.astype(jnp.float32) * scale
    return Compressed(q, scale), e - sent


def compress(grads: PyTree, residual: PyTree):
    """Returns (compressed_tree, new_residual_tree)."""
    leaves, tdef = jax.tree.flatten(grads)
    rleaves, rdef = jax.tree.flatten(residual)
    if rdef != tdef:
        raise ValueError(
            f"residual tree does not match gradient tree (was "
            f"compress_init run on these params?): {rdef} vs {tdef}")
    comp, res = [], []
    for g, r in zip(leaves, rleaves):
        c, nr = _compress_leaf(g, r)
        comp.append(c)
        res.append(nr)
    return jax.tree.unflatten(tdef, comp), jax.tree.unflatten(tdef, res)


def decompress(comp: PyTree) -> PyTree:
    """Dequantize back to fp32 (the receiver side of the all-reduce)."""
    return jax.tree.map(
        lambda c: c.q.astype(jnp.float32) * c.scale, comp, is_leaf=_is_comp)


def compression_ratio(tree: PyTree) -> float:
    """Wire-bytes ratio: original tree vs int8 codes + one fp32 scale
    per leaf (~4x for fp32 gradients)."""
    leaves = jax.tree.leaves(tree)
    orig = sum(l.size * jnp.dtype(l.dtype).itemsize for l in leaves)
    comp = sum(l.size * 1 + 4 for l in leaves)
    return orig / max(comp, 1)
