"""Mesh-aware CSB block partitioning — the paper's PEGroup balancing
(§5.2, Fig. 7b) lifted one level, from PEs to chips.

Inside one device, ``engine/schedule.py`` balances kernel workloads
across a K x L PEGroup torus by donating PE-aligned cycle quanta to
torus neighbours. Here the same cost model and the same donation move
operate across the mesh "model" axis: each device is a station on a
1-D ring, the workload unit is a whole BLOCK-ROW of the CSB grid (a
block-row's output rows live on exactly one device, so the sharded
kernel needs no cross-device scatter — only a final all-gather), and
the cost of a block-row is the PEGroup cycle count the engine would
charge for its blocks (``engine.schedule._block_cycles``, i.e.
``sum_j ceil(m_ij * n_ij / (P*Q))``) — NOT its row count. Skewed
matrices (the paper's diagonal-dense LSTMs, §6.3.2) make naive
equal-row splits 1.5-3x imbalanced; cost-aware placement gets within
~10% of the mean.

Two placement policies mirror the engine's schedulers:

``plan_block_rows(..., policy="equal")``  — naive contiguous equal-row
    split (the baseline dense shardings use; kept for comparison).
``plan_block_rows(..., policy="greedy")`` — LPT seeding followed by
    ring-neighbour donation rounds, the multi-chip twin of
    ``greedy_schedule``'s torus donation.

The plan is pure host-side numpy; ``partition_padded`` applies it to a
``PaddedCSB`` via ``split_block_rows`` to produce the device-stacked
``ShardedCSB`` that ``csb_matvec_sharded`` consumes.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.csb_format import CSBMatrix, PaddedCSB, ShardedCSB
from repro.engine.schedule import _block_cycles


def block_row_cycles(mat: "PaddedCSB | CSBMatrix | tuple",
                     pe: tuple[int, int] = (8, 8)) -> np.ndarray:
    """(Br,) per-block-row PEGroup cycle cost under a P x Q group —
    the engine's own cost model, summed over the block columns each
    device would execute sequentially. ``mat`` may also be a raw
    ``(m, n)`` pair of (Br, Bc) survivor-count grids."""
    if isinstance(mat, PaddedCSB):
        br, bc = mat.grid
        m = np.asarray(mat.m).reshape(br, bc).astype(np.int64)
        n = np.asarray(mat.n).reshape(br, bc).astype(np.int64)
    elif isinstance(mat, tuple):
        m = np.asarray(mat[0], np.int64)
        n = np.asarray(mat[1], np.int64)
    else:
        m = mat.m.astype(np.int64)
        n = mat.n.astype(np.int64)
    p, q = pe
    return _block_cycles(m, n, p, q).sum(axis=1)


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """Block-row -> device placement plus the cycle accounting behind it."""

    assignment: tuple[tuple[int, ...], ...]   # block-row ids per device
    device_cycles: tuple[int, ...]            # planned cycles per device
    policy: str

    @property
    def n_dev(self) -> int:
        return len(self.assignment)

    @property
    def imbalance(self) -> float:
        """max/mean per-device cycles — 1.0 is perfect balance."""
        cyc = np.asarray(self.device_cycles, np.float64)
        mean = cyc.mean()
        return float(cyc.max() / mean) if mean > 0 else 1.0

    def as_dict(self) -> dict:
        return {
            "policy": self.policy,
            "n_dev": self.n_dev,
            "device_cycles": list(self.device_cycles),
            "imbalance": round(self.imbalance, 4),
        }


def _equal_split(n_rows: int, n_dev: int) -> list[list[int]]:
    """Contiguous ceil(Br/D)-row chunks — what a plain reshape-style
    dense sharding would do."""
    per = -(-n_rows // n_dev)
    return [list(range(d * per, min((d + 1) * per, n_rows)))
            for d in range(n_dev)]


def _ring_donate(assignment: list[list[int]], cost: np.ndarray,
                 rounds: int = 8) -> None:
    """Donate block-rows to ring neighbours until balanced (in place).

    The multi-chip version of ``greedy_schedule``'s torus donation: the
    heaviest-loaded devices try to hand a block-row to whichever ring
    neighbour is lighter, choosing the row whose cost best matches half
    the load gap (the engine's ``give = gap // 2`` waterfill, rounded
    to whole block-rows). A move only happens when it strictly lowers
    the pair's max load, so the loop monotonically improves and
    terminates.
    """
    n_dev = len(assignment)
    if n_dev <= 1:
        return
    load = np.array([sum(cost[r] for r in rows) for rows in assignment],
                    np.int64)
    for _ in range(rounds):
        moved = False
        for d in np.argsort(load)[::-1]:
            for t in sorted({(d - 1) % n_dev, (d + 1) % n_dev},
                            key=lambda i: load[i]):
                gap = load[d] - load[t]
                if gap <= 0 or not assignment[d]:
                    continue
                give = gap // 2
                row = min(assignment[d],
                          key=lambda r: abs(int(cost[r]) - give))
                c = int(cost[row])
                if c == 0 or max(load[d] - c, load[t] + c) >= load[d]:
                    continue
                assignment[d].remove(row)
                assignment[t].append(row)
                load[d] -= c
                load[t] += c
                moved = True
        if not moved:
            break


def plan_block_rows(cycles: Sequence[int] | np.ndarray, n_dev: int,
                    policy: str = "greedy",
                    donation_rounds: int = 8) -> PartitionPlan:
    """Place ``len(cycles)`` block-rows on ``n_dev`` devices.

    ``policy="equal"``  — contiguous equal-row chunks (naive baseline).
    ``policy="greedy"`` — LPT (heaviest row to lightest device) seeding
    plus ring-donation refinement; both steps work on engine cycle
    costs, so a diagonal-dense matrix spreads its heavy rows.
    """
    cost = np.asarray(cycles, np.int64)
    br = len(cost)
    if n_dev < 1:
        raise ValueError("n_dev must be >= 1")
    if policy == "equal":
        assignment = _equal_split(br, n_dev)
    elif policy == "greedy":
        assignment = [[] for _ in range(n_dev)]
        load = np.zeros(n_dev, np.int64)
        for r in np.argsort(cost, kind="stable")[::-1]:
            d = int(np.argmin(load))
            assignment[d].append(int(r))
            load[d] += cost[r]
        _ring_donate(assignment, cost, rounds=donation_rounds)
    else:
        raise ValueError(f"unknown policy {policy!r}")
    for rows in assignment:
        rows.sort()
    return PartitionPlan(
        assignment=tuple(tuple(rows) for rows in assignment),
        device_cycles=tuple(int(sum(cost[r] for r in rows))
                            for rows in assignment),
        policy=policy,
    )


def partition_padded(p: PaddedCSB, n_dev: int, *,
                     pe: tuple[int, int] = (8, 8),
                     policy: str = "greedy"
                     ) -> tuple[PartitionPlan, ShardedCSB]:
    """Plan + apply: returns the plan and the device-stacked shards.

    With :mod:`repro.obs` enabled, each application records the
    per-device cycle balance (the paper's workload-imbalance metric,
    §6.3.2) at execution time: the ``dist/csb_partition/imbalance``
    gauge accumulates one max/mean sample per partitioned weight, and a
    trace instant carries the full per-device cycle vector."""
    plan = plan_block_rows(block_row_cycles(p, pe=pe), n_dev, policy=policy)
    from repro.obs import metrics as obs_metrics, trace as obs_trace
    reg = obs_metrics.get()
    if reg is not None:
        reg.gauge("dist/csb_partition/imbalance").set(plan.imbalance)
        reg.gauge("dist/csb_partition/max_device_cycles").set(
            max(plan.device_cycles))
    tr = obs_trace.get()
    if tr is not None:
        tr.instant("dist/csb_partition",
                   args={"imbalance": round(plan.imbalance, 4),
                         "device_cycles": list(plan.device_cycles),
                         "policy": plan.policy})
    return plan, p.split_block_rows(plan.assignment)
