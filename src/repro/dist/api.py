"""Context-scoped sharding-constraint application.

``shard(x, "residual")`` is the single call sites use to pin a logical
activation to the mesh. Which ``PartitionSpec`` (if any) that name maps
to is decided by the active :class:`Rules` installed with
:func:`use_rules` — model code never mentions meshes or axis names, so
the same forward function serves the single-device CPU tests and the
production 16x16 pod unchanged.

Outside a ``use_rules`` scope (or inside one whose mesh is trivial)
``shard`` is the identity, returning its argument object untouched.
Unknown logical names and dims that do not divide their mesh axis also
pass through unchanged, so reduced smoke configs never trip a GSPMD
divisibility error.
"""
from __future__ import annotations

import contextlib
import math
import threading
from typing import Any, Mapping

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


class Rules:
    """Immutable mapping: logical activation name -> PartitionSpec.

    Optionally carries the mesh the specs refer to; without a mesh the
    rules are inert (``shard`` stays the identity), which keeps
    single-device paths untouched.
    """

    def __init__(self, table: Mapping[str, P] | None = None, mesh=None):
        self._table = dict(table or {})
        self.mesh = mesh

    def get(self, name: str, default: P | None = None) -> P | None:
        return self._table.get(name, default)

    def __contains__(self, name: str) -> bool:
        return name in self._table

    def items(self):
        return self._table.items()

    def updated(self, **specs: P) -> "Rules":
        """A new Rules with the given names added/overridden."""
        return Rules({**self._table, **specs}, mesh=self.mesh)

    def __repr__(self) -> str:  # pragma: no cover
        return f"Rules({self._table!r}, mesh={self.mesh!r})"


_state = threading.local()


def current_rules() -> Rules | None:
    """The innermost active Rules, or None outside any ``use_rules``."""
    stack = getattr(_state, "stack", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def use_rules(rules: Rules):
    """Install ``rules`` for the dynamic extent of the block (nestable;
    exiting restores the outer rules)."""
    stack = getattr(_state, "stack", None)
    if stack is None:
        stack = _state.stack = []
    stack.append(rules)
    try:
        yield rules
    finally:
        stack.pop()


def fit_spec(spec: P, shape: tuple[int, ...], mesh) -> P | None:
    """Clip ``spec`` to what ``shape`` can actually carry on ``mesh``.

    Drops axis assignments whose dim does not divide the mesh axis size
    (or that name axes the mesh lacks). Returns None when nothing
    survives — the caller should skip the constraint entirely.
    """
    names = tuple(mesh.axis_names)
    entries = []
    any_live = False
    for i, dim in enumerate(shape):
        e = spec[i] if i < len(spec) else None
        if e is None:
            entries.append(None)
            continue
        axes = e if isinstance(e, tuple) else (e,)
        if not all(ax in names for ax in axes):
            entries.append(None)
            continue
        total = math.prod(mesh.shape[ax] for ax in axes)
        if dim % total != 0:
            entries.append(None)
            continue
        entries.append(e)
        any_live = any_live or total > 1
    if not any_live:
        return None
    return P(*entries)


def shard(x: Any, name: str, *, fallback: str | None = None) -> Any:
    """Constrain ``x`` to the active rule for ``name`` (identity when no
    rules/mesh are active, the name is unknown, or no dim fits).

    ``fallback="replicate"`` pins ``x`` fully replicated when the rule
    exists but no dim fits, instead of leaving the layout to GSPMD
    propagation. Call sites whose downstream math re-chunks the tensor
    (rope's rotate-half split/concat) use this: letting a weight's
    output-dim sharding propagate into those reshapes triggers XLA's
    involuntary-full-rematerialization transition, which the CPU SPMD
    backend has been observed to compile to WRONG numerics — an
    explicit layout sidesteps the transition entirely.
    """
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.get(name)
    if spec is None:
        return x
    mesh = rules.mesh
    if mesh is None or math.prod(mesh.shape.values()) <= 1:
        return x
    fitted = fit_spec(spec, x.shape, mesh)
    if fitted is None:
        if fallback != "replicate":
            return x
        fitted = P(*([None] * len(x.shape)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, fitted))


def replicated(x: Any) -> Any:
    """Pin ``x`` fully replicated on the active rules mesh (identity
    without one, like ``shard``).

    Pallas-call boundaries use this: the interpret-mode grid loop
    lowers to while/dynamic-slice HLO whose layouts GSPMD must guess,
    and a guessed split triggers the involuntary-full-rematerialization
    transition described in :func:`shard` — observed to compile to
    WRONG numerics on the CPU SPMD backend. Replicated operands keep
    the whole loop replicated; for the paged-attention kernel that is
    also the natural layout, since any decode slot may address any
    page of the pool."""
    rules = current_rules()
    if rules is None:
        return x
    mesh = rules.mesh
    if mesh is None or math.prod(mesh.shape.values()) <= 1:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*([None] * len(x.shape)))))
