"""Sharding-rule derivation: model/mesh/policy -> PartitionSpec trees.

This is the CSB balancing idea one level up (paper §5.2): instead of
PEGroups trading cycle quanta, the device mesh trades tensor tiles — and
just as the engine's scheduler owns the block layout, this module owns
every spec so train/dryrun/serve agree on one mapping.

Conventions (megatron-style, guarded):

* "model" axis — tensor parallelism. Column-parallel weights (qkv /
  gate / up / head) shard their output dim; row-parallel weights
  (``wo``/``w_down``/``w_out``) shard their input dim; embeddings shard
  the vocab dim; MoE expert tensors shard the expert dim.
* "data" (+ "pod") axes — batch/FSDP parallelism.
* Every assignment is divisibility-guarded against the mesh axis size,
  so reduced smoke configs simply replicate what cannot shard.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from .api import Rules, fit_spec

PyTree = Any

# weights whose *input* dim is model-sharded (their matmul reduces over
# the sharded dim, putting the all-reduce after the projection)
_ROW_PARALLEL = {"wo", "w_down", "w_out"}
# per-expert MoE tensors: (L, E, in, out) — shard the expert axis
_EXPERT_WEIGHTS = {"w_gate", "w_up", "w_down"}


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Per-cell distribution knobs (derived in configs/registry.py).

    fsdp            — additionally shard weights over the data axes
                      (30B+ models; weights do not fit replicated).
    seq_shard       — sequence parallelism: residuals shard their seq
                      dim over "model" (saves activation memory; off for
                      MoE archs, see registry).
    shard_cache_seq — decode caches shard their time dim over "model"
                      (a 32k cache replicated 16x is pure waste; MQA
                      makes head-sharding impossible, seq always works).
    """

    fsdp: bool = False
    seq_shard: bool = False
    shard_cache_seq: bool = True


def _axis_size(mesh, ax) -> int:
    return mesh.shape[ax] if ax in tuple(mesh.axis_names) else 0


def _dp_axes(mesh) -> tuple[str, ...]:
    return tuple(ax for ax in mesh.axis_names if ax != "model")


def _dp_entry(mesh, batch: int | None = None):
    """The spec entry for a batch-like dim (None when it cannot shard)."""
    dp = _dp_axes(mesh)
    if not dp:
        return None
    total = math.prod(mesh.shape[ax] for ax in dp)
    if batch is not None and batch % max(total, 1) != 0:
        return None
    return dp if len(dp) > 1 else dp[0]


def _path_keys(path) -> list[str]:
    return [str(getattr(e, "key", getattr(e, "idx", e))) for e in path]


def _leaf_spec(path, leaf, mesh, policy: ShardingPolicy) -> P:
    keys = _path_keys(path)
    name = keys[-1] if keys else ""
    in_layers = bool(keys) and keys[0] == "layers"
    shape = tuple(leaf.shape)
    nd = len(shape)
    # effective weight rank ignores the stacked layer axis
    eff = nd - 1 if in_layers else nd

    entries: list[Any] = [None] * nd
    model_dim = None
    if eff >= 2:
        if name == "embed":
            model_dim = nd - 2                    # vocab dim
        elif name in _EXPERT_WEIGHTS and eff >= 3:
            model_dim = nd - 3                    # expert dim
        elif name in _ROW_PARALLEL:
            model_dim = nd - 2                    # input dim
        else:
            model_dim = nd - 1                    # output dim
    if model_dim is not None:
        msize = _axis_size(mesh, "model")
        if msize and shape[model_dim] % msize == 0:
            entries[model_dim] = "model"

    if policy.fsdp and eff >= 2:
        dsize = _axis_size(mesh, "data")
        cands = [d for d in range(nd)
                 if entries[d] is None and not (in_layers and d == 0)]
        cands.sort(key=lambda d: -shape[d])
        for d in cands:
            if dsize and shape[d] % dsize == 0:
                entries[d] = "data"
                break
    return P(*entries)


def param_specs(cfg, params: PyTree, mesh, policy: ShardingPolicy) -> PyTree:
    """PartitionSpec per param leaf (works on arrays or ShapeDtypeStructs,
    so the dry-run path derives shardings with zero allocation)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf, mesh, policy), params)


def activation_rules(cfg, mesh, policy: ShardingPolicy, *,
                     global_batch: int | None = None) -> Rules:
    """The logical-name table ``models/*`` routes through ``shard()``.

    See repro/dist/__init__.py for the full name -> layout table.
    """
    dp = _dp_entry(mesh, global_batch)
    seq = "model" if policy.seq_shard else None
    cache_seq = "model" if policy.shard_cache_seq else None
    if cfg.n_codebooks:
        logits = P(dp, None, None, "model")       # (B, ck, K, V)
    else:
        logits = P(dp, None, "model")             # (B, ck, V)
    table = {
        "residual": P(dp, seq, None),             # (B, S, d)
        "logits": logits,
        "kv_cache": P(dp, cache_seq, None, None),  # (B, T, KV, D)
        "mla_cache": P(dp, cache_seq, None),      # (B, T, kv_lora)
        # paged pools: pages data-parallel, page dims replicated (the
        # page table is replicated, so every replica can reach any page)
        "kv_pages": P(dp, None, None, None),      # (N, P, KV, D)
        "mla_pages": P(dp, None, None),           # (N, P, kv_lora)
        "attn_q": P(dp, None, "model", None),     # (B, S, H, D)
        "attn_kv": P(dp, None, "model", None),    # (B, S, KV, D)
        # SSD block streams (B, S, C): batch-parallel only. The tag is
        # load-bearing — see layers.ssd_block_apply (call sites use
        # fallback="replicate" so an unsplittable batch pins the whole
        # chunked scan replicated instead of letting GSPMD guess)
        "ssd_inner": P(dp, None, None),
        "moe_groups": P(dp, None, None),          # (G, C, d)
        "moe_dispatch": P(dp, None, "model", None),  # (G, C, E, cap)
        "moe_experts": P(dp, "model", None, None),   # (G, E, cap, d)
    }
    return Rules(table, mesh=mesh)


def csb_shard_specs(obj: Any, mesh, *, axis: str = "model",
                    policy: "ShardingPolicy | None" = None) -> Any:
    """PartitionSpec tree for CSB weights, derived alongside the dense
    ``param_specs`` (same guards, same "model" axis).

    ``ShardedCSB`` leaves (device-stacked by ``dist.csb_partition``)
    shard their leading device axis over ``axis`` when the split width
    matches the mesh; anything that cannot shard — an unsplit
    ``PaddedCSB``, or a split whose device count mismatches — is fully
    replicated, mirroring the divisibility guards above. Dense leaves
    fall through to the ``param_specs`` placement rules under
    ``policy`` (default: no FSDP). Returns a structure-matched tree of
    PartitionSpecs (works on whole param trees via ``tree_map`` with
    CSB containers as leaves) — the one placement call a serve path
    needs for a mixed dense/CSB parameter tree.
    """
    from repro.core.csb_format import PaddedCSB, ShardedCSB

    policy = policy or ShardingPolicy()

    def one(path, leaf):
        if isinstance(leaf, ShardedCSB):
            ok = _axis_size(mesh, axis) == leaf.n_dev and leaf.n_dev > 1
            lead = axis if ok else None
            return ShardedCSB(
                vals=P(lead, None, None, None),
                row_idx=P(lead, None, None),
                col_idx=P(lead, None, None),
                m=P(lead, None), n=P(lead, None),
                shape=leaf.shape, grid=leaf.grid, block=leaf.block,
                row_map=leaf.row_map,
            )
        if isinstance(leaf, PaddedCSB):
            return PaddedCSB(
                vals=P(None, None, None), row_idx=P(None, None),
                col_idx=P(None, None), m=P(None), n=P(None),
                shape=leaf.shape, grid=leaf.grid, block=leaf.block,
            )
        return _leaf_spec(path, leaf, mesh, policy)

    def is_csb(x):
        return isinstance(x, (PaddedCSB, ShardedCSB))

    if is_csb(obj):
        return one((), obj)
    return jax.tree_util.tree_map_with_path(one, obj, is_leaf=is_csb)


def batch_specs(cfg, kind: str, mesh, *,
                global_batch: int | None = None) -> dict[str, P]:
    """Input-batch shardings per key for a train/prefill/decode step."""
    dp = _dp_entry(mesh, global_batch)
    tok = P(dp, None, None) if cfg.n_codebooks else P(dp, None)
    specs = {"tokens": tok}
    if kind == "train":
        specs["labels"] = tok
    if cfg.n_img_tokens:
        specs["img_embeds"] = P(dp, None, None)
    if kind == "decode":
        specs["pos"] = P(dp)          # (B,) per-slot positions
    return specs


def cache_specs(cfg, cache: PyTree, mesh,
                policy: ShardingPolicy, *, paged: bool = False) -> PyTree:
    """Decode-cache shardings. Leaves carry a leading stacked-layer axis
    (always replicated — the decode scan iterates it).

    ``paged=True`` describes the page-pool layout (``serve.paging``):
    time-keyed leaves are pools shaped (L, N_pages, page_size, ...)
    shared by every slot, sharded over the data axes on the *page* dim
    (each replica holds a shard of the pool; the page table stays
    replicated so any slot can reach any page — GSPMD routes the
    cross-shard gathers). State leaves (SSM/conv) keep their per-slot
    batch sharding in both modes. Divisibility guards apply as
    everywhere: a pool whose page count (incl. the +1 scratch page)
    does not divide the data axes simply replicates.
    """
    dp = _dp_entry(mesh)
    cs = "model" if policy.shard_cache_seq else None

    def one(path, leaf):
        name = _path_keys(path)[-1]
        nd = len(leaf.shape)
        if paged and name in ("k", "v"):        # (L, N, P, KV, D)
            spec = P(None, dp, None, None, None)
        elif paged and name in ("c_kv", "k_rope"):  # (L, N, P, d)
            spec = P(None, dp, None, None)
        elif name in ("k", "v"):          # (L, B, T, KV, D)
            spec = P(None, dp, cs, None, None)
        elif name in ("c_kv", "k_rope"):  # (L, B, T, lora/rd)
            spec = P(None, dp, cs, None)
        elif name == "ssm":               # (L, B, H, P, N)
            spec = P(None, dp, "model", None, None)
        else:                             # conv state etc: batch only
            spec = P(None, dp)
        fitted = fit_spec(spec, tuple(leaf.shape), mesh)
        return fitted if fitted is not None else P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(one, cache)
