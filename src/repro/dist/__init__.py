"""repro.dist — the sharding subsystem between the CSB kernels and every
scale path (train, dryrun, serve).

The paper balances structured-sparse work across PEGroups (§5.2); this
package applies the same idea one level up, balancing block grids and
dense weights across a JAX device mesh. Model code stays mesh-agnostic:
it tags activations with *logical names* via ``shard(x, name)``, and the
launcher decides what (if anything) each name means by installing
:class:`Rules` with :func:`use_rules`.

API surface
===========

``api``       — ``shard(x, name)`` context-scoped constraint application,
                ``Rules`` (logical name -> PartitionSpec, ``.updated()``
                for overrides), ``use_rules`` context manager (nestable),
                ``current_rules``, ``fit_spec`` divisibility guard.
                ``shard`` is the identity outside ``use_rules``, outside
                a (non-trivial) mesh, for unknown names, and for dims
                that do not divide their mesh axis.
``rules``     — ``ShardingPolicy`` (fsdp / seq_shard / shard_cache_seq),
                ``param_specs`` / ``activation_rules`` / ``batch_specs``
                / ``cache_specs`` derivation from a ModelConfig + mesh.
                All of these accept abstract (ShapeDtypeStruct) trees so
                the dry-run path never allocates.
``compress``  — int8 error-feedback gradient compression:
                ``compress_init`` / ``compress`` / ``decompress`` /
                ``compression_ratio`` with per-leaf scale and residual
                carry (~4x all-reduce traffic reduction).
``csb_partition`` — mesh-aware CSB block partitioning (paper §5.2
                across chips): ``block_row_cycles`` engine cost model,
                ``plan_block_rows`` greedy (LPT + ring donation) or
                equal placement, ``partition_padded`` producing the
                device-stacked ``ShardedCSB`` that
                ``kernels.csb_sharded.csb_matvec_sharded`` executes;
                ``csb_shard_specs`` (in ``rules``) derives the matching
                PartitionSpecs alongside the dense ``param_specs``.

Logical-name table (who applies it, and the layout it requests)
===============================================================

=============  =========================  ===============================
name           call site                  layout (guarded)
=============  =========================  ===============================
residual       lm.layer_apply / embed     (B@dp, S[@model if SP], d)
logits         lm.lm_loss CE chunks       (B@dp, ck, [K,] V@model)
kv_cache       lm prefill/init_cache      (B@dp, T@model, KV, D)
mla_cache      lm prefill/init_cache      (B@dp, T@model, kv_lora)
kv_pages       lm init_paged_cache        (N@dp, P, KV, D)
mla_pages      lm init_paged_cache        (N@dp, P, kv_lora)
attn_q         layers.attn_qkv            (B@dp, S, H@model, D)
attn_kv        layers.attn_qkv            (B@dp, S, KV@model, D)
moe_groups     layers.moe_apply           (G@dp, C, d)
moe_dispatch   layers.moe_apply           (G@dp, C, E@model, cap)
moe_experts    layers.moe_apply           (G@dp, E@model, cap, d)
=============  =========================  ===============================

``dp`` is the data axis (or ("pod", "data") on the multi-pod mesh);
``@model`` entries are dropped per-tensor when the dim does not divide
the mesh axis size, so reduced CPU configs replicate instead of erroring.
"""
from .api import Rules, current_rules, fit_spec, shard, use_rules
from .compress import (
    Compressed,
    compress,
    compress_init,
    compression_ratio,
    decompress,
)
from .csb_partition import (
    PartitionPlan,
    block_row_cycles,
    partition_padded,
    plan_block_rows,
)
from .rules import (
    ShardingPolicy,
    activation_rules,
    batch_specs,
    cache_specs,
    csb_shard_specs,
    param_specs,
)

__all__ = [
    "Rules", "current_rules", "fit_spec", "shard", "use_rules",
    "ShardingPolicy", "activation_rules", "batch_specs", "cache_specs",
    "csb_shard_specs", "param_specs",
    "PartitionPlan", "block_row_cycles", "partition_padded",
    "plan_block_rows",
    "Compressed", "compress", "compress_init", "compression_ratio",
    "decompress",
]
