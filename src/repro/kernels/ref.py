"""Pure-jnp oracles for the CSB-MVM kernel.

``densify`` reconstructs the dense matrix from the padded CSB arrays with
one-hot scatter einsums; the matvec oracle is then an ordinary matmul.
These are the ground truth every Pallas kernel run is asserted against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.csb_format import PaddedCSB


def densify(p: PaddedCSB) -> jax.Array:
    """(out, in) dense matrix equal to the CSB contents."""
    nb, pm, pn = p.vals.shape
    br, bc = p.grid
    bm, bn = p.block
    rmask = (jnp.arange(pm)[None, :] < p.m[:, None]).astype(p.vals.dtype)
    cmask = (jnp.arange(pn)[None, :] < p.n[:, None]).astype(p.vals.dtype)
    roh = jax.nn.one_hot(p.row_idx, bm, dtype=p.vals.dtype) * rmask[..., None]
    coh = jax.nn.one_hot(p.col_idx, bn, dtype=p.vals.dtype) * cmask[..., None]
    # scatter kernel (Pm,Pn) into the (bm,bn) block frame
    blocks = jnp.einsum("bkr,bkl,blc->brc", roh, p.vals, coh)
    w = blocks.reshape(br, bc, bm, bn).transpose(0, 2, 1, 3)
    w = w.reshape(br * bm, bc * bn)
    return w[: p.shape[0], : p.shape[1]]


def csb_mvm_ref(p: PaddedCSB, x: jax.Array) -> jax.Array:
    """y = x @ W^T with W the CSB matrix; x: (..., in_dim) -> (..., out_dim).

    Accumulates in fp32 like the kernel does.
    """
    w = densify(p).astype(jnp.float32)
    y = jnp.einsum("...i,oi->...o", x.astype(jnp.float32), w)
    return y.astype(x.dtype)
