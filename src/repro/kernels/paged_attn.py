"""Pallas paged-attention decode kernel (vLLM PagedAttention-style).

The paged serve path keeps every slot's KV in a shared page pool
``(N_pages, P, ...)`` indexed through a dense ``(slots, max_pages)``
int32 page table (``serve.paging.PagePool.device_table``). The XLA
fallback (``models.layers.paged_gather``) materializes each slot's
logical extent as a ``(B, max_pages*P, ...)`` gather in HBM before
every decode attention — exactly the kind of indirection CSB-RNN's
kernel co-design removes from the hot loop (PAPER.md §IV–V).

This kernel walks the page table *inside* the Pallas program instead:
grid ``(slots,)``, one program per decode slot, each step reading its
row of the table and dynamic-slicing pages straight out of the pool
ref into VMEM. No ``(B, max_pages*P)`` array ever exists in the traced
program — the test suite asserts the gather shape is absent from the
kernel path's jaxpr.

Numerics mirror the fallback exactly: scores are computed per KV group
in fp32 (``preferred_element_type``), masked to the slot's true length
with ``kpos <= pos`` (optional sliding ``window``), softmaxed over the
full logical extent, then contracted against the value pages. Garbage
rows (inactive slots mapped to the scratch page, pad pages past a
slot's extent) fall outside the mask and underflow to exactly 0, same
as the gather path.

MLA routes through the same kernel via the optional rope score term:
``q2``/``k2_pool`` add ``q2 . k2`` to the (compressed-latent) scores,
and the value pool is the ``c_kv`` pool itself — standard MHA with one
KV group and a value width different from the key width.

``interpret`` selection mirrors ``csb_mvm.default_interpret``: TPU/GPU
compile, CPU interprets, and the CI golden lane
(REPRO_FORCE_TPU_INTERPRET=1) takes the compiled branch under
``pltpu.force_tpu_interpret_mode``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .csb_mvm import default_interpret

F32 = jnp.float32


def _kernel(q_ref, tab_ref, pos_ref, *rest, rep: int, scale: float,
            window: int | None, has_rope: bool):
    """One grid step = one decode slot's attention over its pages."""
    if has_rope:
        q2_ref, k_ref, v_ref, k2_ref, o_ref = rest
    else:
        k_ref, v_ref, o_ref = rest
        q2_ref = k2_ref = None
    mp = tab_ref.shape[1]
    psz = k_ref.shape[1]
    kv = k_ref.shape[2]
    t = mp * psz
    pos = pos_ref[0, 0]

    # walk the page table: dynamic-slice each mapped page out of the
    # pool ref (VMEM-resident per slot, never a (B, T) HBM gather)
    k_pages, v_pages, k2_pages = [], [], []
    for j in range(mp):
        pg = tab_ref[0, j]
        k_pages.append(k_ref[pl.ds(pg, 1)][0])       # (P, KV, D)
        v_pages.append(v_ref[pl.ds(pg, 1)][0])       # (P, KV, Dv)
        if has_rope:
            k2_pages.append(k2_ref[pl.ds(pg, 1)][0])
    kcat = jnp.concatenate(k_pages, axis=0)          # (T, KV, D)
    vcat = jnp.concatenate(v_pages, axis=0)          # (T, KV, Dv)
    k2cat = jnp.concatenate(k2_pages, axis=0) if has_rope else None

    kpos = jax.lax.broadcasted_iota(jnp.int32, (rep, t), 1)
    mask = kpos <= pos
    if window is not None:
        mask &= kpos > pos - window

    outs = []
    for g in range(kv):
        qg = q_ref[0, g * rep:(g + 1) * rep, :].astype(kcat.dtype)
        kg = kcat[:, g, :]                           # (T, D)
        sc = jax.lax.dot_general(
            qg, kg, (((1,), (1,)), ((), ())),
            preferred_element_type=F32)              # (rep, T)
        if has_rope:
            q2g = q2_ref[0, g * rep:(g + 1) * rep, :].astype(k2cat.dtype)
            sc = sc + jax.lax.dot_general(
                q2g, k2cat[:, g, :], (((1,), (1,)), ((), ())),
                preferred_element_type=F32)
        sc = jnp.where(mask, sc * scale, -1e30)
        p = jax.nn.softmax(sc, axis=-1)
        outs.append(jax.lax.dot_general(
            p.astype(vcat.dtype), vcat[:, g, :], (((1,), (0,)), ((), ())),
            preferred_element_type=F32))             # (rep, Dv)
    o_ref[0] = jnp.concatenate(outs, axis=0)         # (H, Dv)


def paged_attn_decode(
    q: jax.Array,            # (B, H, D)
    k_pool: jax.Array,       # (N, P, KV, D)
    v_pool: jax.Array,       # (N, P, KV, Dv)
    page_table: jax.Array,   # (B, max_pages) int32
    pos,                     # scalar or (B,) decode positions
    *,
    scale: float,
    q2: jax.Array | None = None,       # (B, H, D2) rope query (MLA)
    k2_pool: jax.Array | None = None,  # (N, P, KV, D2) rope key pool
    window: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Per-slot paged decode attention; returns (B, H, Dv) fp32.

    ``pos`` is the position being decoded this step, scalar (whole
    batch at one depth) or (B,) (continuous batching); key positions
    ``kpos <= pos`` attend, everything else — pad pages, scratch-page
    garbage of inactive slots — masks to exactly 0.
    """
    if interpret is None:
        interpret = default_interpret()
    b, h, _ = q.shape
    n, psz, kv = k_pool.shape[:3]
    mp = page_table.shape[1]
    dv = v_pool.shape[-1]
    assert h % kv == 0, (h, kv)
    rep = h // kv
    has_rope = q2 is not None
    assert has_rope == (k2_pool is not None)

    pos2 = jnp.broadcast_to(
        jnp.asarray(pos, jnp.int32).reshape(-1, 1), (b, 1))
    table = jnp.asarray(page_table, jnp.int32)

    args = [q, table, pos2]
    in_specs = [
        pl.BlockSpec((1, h, q.shape[-1]), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, mp), lambda i: (i, 0)),
        pl.BlockSpec((1, 1), lambda i: (i, 0)),
    ]
    if has_rope:
        args.append(q2)
        in_specs.append(
            pl.BlockSpec((1, h, q2.shape[-1]), lambda i: (i, 0, 0)))
    # pools ride in whole (index map pinned to block 0) so the kernel
    # can dynamic-slice arbitrary pages out of them
    for pool in (k_pool, v_pool) + ((k2_pool,) if has_rope else ()):
        args.append(pool)
        in_specs.append(pl.BlockSpec(
            pool.shape, lambda *_, nd=pool.ndim: (0,) * nd))

    out = pl.pallas_call(
        functools.partial(_kernel, rep=rep, scale=scale, window=window,
                          has_rope=has_rope),
        grid=(b,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, h, dv), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dv), F32),
        interpret=interpret,
    )(*args)
    return out


__all__ = ["paged_attn_decode"]
