"""Mesh-sharded CSB matvec: per-device Pallas kernels + output all-gather.

``csb_matvec_sharded(s, x, mesh=...)`` runs the CSB-MVM kernel on each
device's local block-row shard (a ``ShardedCSB`` built by
``repro.dist.csb_partition``) under ``shard_map``, all-gathers the
per-device output rows along the mesh "model" axis, and permutes the
gathered rows back to the original block-row order (the planner
assigns rows by cycle cost, not contiguously).

Collective-matmul pipeline: each device's block-rows are split into
``overlap`` chunks and the shard_map body interleaves one Pallas MVM +
one all-gather per chunk. The all-gather of a finished chunk is
independent of every later chunk's compute, so an async-collective
backend (TPU) starts gathering completed rows while the final chunk's
kernel is still running — compute hides the collective instead of
serializing behind it. Row chunks are disjoint (the kernel's grid is
independent per block-row), so per-row numerics are bit-identical for
any ``overlap``; only the gathered layout changes, and the row
unpermute (folded with the chunk reorder into one ``take``) restores
the original order exactly as before.

Device placement quality is the planner's job; this wrapper executes
whatever ``row_map`` it is handed, exactly as ``csb_mvm_pallas``
executes whatever block layout the engine scheduler chose. Pad rows
(devices with fewer block-rows than the max) carry ``m = n = 0`` and
the kernel masks them to zero, so they cost one grid step but never
corrupt the gather.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.csb_format import ShardedCSB, csb_output_permutation
from .csb_mvm import csb_mvm_pallas, default_interpret
from .ops import pad_to_grid

try:                                      # jax >= 0.6: top-level API
    from jax import shard_map as _shard_map
except ImportError:                       # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map


def _shmap(f, mesh, in_specs, out_specs):
    """shard_map across jax versions: pallas_call has no replication
    rule, so the check must be off — the knob is ``check_rep`` on older
    jax and ``check_vma`` after the rename."""
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
    except TypeError:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)


def _chunk_bounds(rpd: int, overlap: int) -> list[tuple[int, int]]:
    """Split ``rpd`` block-rows into ``overlap`` contiguous chunks,
    sizes as even as possible (first chunks take the remainder)."""
    overlap = max(1, min(overlap, rpd))
    base, rem = divmod(rpd, overlap)
    bounds, start = [], 0
    for i in range(overlap):
        size = base + (1 if i < rem else 0)
        bounds.append((start, start + size))
        start += size
    return bounds


def _chunk_order(n_dev: int, rpd: int, bm: int,
                 bounds: list[tuple[int, int]]) -> np.ndarray:
    """Map device-order gather positions -> chunked-gather positions.

    The single-gather layout is ``[dev0 rows 0..rpd) | dev1 ...]``;
    chunked gathers concatenate ``[all devs' chunk0 | all devs' chunk1
    | ...]``. ``order[sp] = cp`` lets the wrapper fold the reorder into
    the existing row unpermute: ``take(chunked, order)[perm] ==
    take(chunked, order[perm])``."""
    order = np.empty(n_dev * rpd * bm, np.int64)
    base = 0
    for s_, e_ in bounds:
        size = e_ - s_
        for d in range(n_dev):
            for r in range(s_, e_):
                sp = (d * rpd + r) * bm
                cp = base + (d * size + (r - s_)) * bm
                order[sp:sp + bm] = np.arange(cp, cp + bm)
        base += n_dev * size * bm
    return order


@functools.lru_cache(maxsize=None)
def _sharded_fn(mesh, axis_name: str, grid: tuple[int, int],
                block: tuple[int, int], rpd: int,
                row_map: tuple[tuple[int, ...], ...],
                batch_tile: int, group: int, interpret: bool,
                overlap: int):
    """Jitted (shards..., xp) -> gathered-and-unpermuted output, cached
    per static configuration — the sharded twin of ops._run's jit cache,
    so eager serving loops don't re-trace the kernel every call."""
    br, bc = grid
    bm, bn = block
    spec1 = P(axis_name)
    # batch stays sharded over the non-model axes (data parallelism is
    # orthogonal to the block-row split); only the feature/row dims are
    # replicated along the model axis
    dp = tuple(ax for ax in mesh.axis_names if ax != axis_name)
    dp_entry = dp if len(dp) > 1 else (dp[0] if dp else None)
    xspec = P(dp_entry, None)

    n_dev = mesh.shape[axis_name]
    bounds = _chunk_bounds(rpd, overlap)

    def body(vals, ridx, cidx, m, n, xl):
        # local shard: leading device axis is 1 here — squeeze it, then
        # pipeline chunk-MVM -> chunk-all-gather so each gather only
        # waits on its own rows (collective matmul: the last chunk's
        # kernel runs while earlier chunks are already in flight)
        v, r, c, mm, nn = vals[0], ridx[0], cidx[0], m[0], n[0]
        parts = []
        for s_, e_ in bounds:
            y = csb_mvm_pallas(
                v[s_ * bc:e_ * bc], r[s_ * bc:e_ * bc],
                c[s_ * bc:e_ * bc], mm[s_ * bc:e_ * bc],
                nn[s_ * bc:e_ * bc], xl,
                grid=(e_ - s_, bc), block=(bm, bn),
                batch_tile=batch_tile, group=group, interpret=interpret,
            )                                        # (Bp, (e-s)*bm)
            parts.append(
                jax.lax.all_gather(y, axis_name, axis=1, tiled=True))
        if len(parts) == 1:
            return parts[0]
        return jnp.concatenate(parts, axis=1)        # (Bp, D*rpd*bm)

    shmapped = _shmap(
        body, mesh,
        in_specs=(spec1, spec1, spec1, spec1, spec1, xspec),
        out_specs=xspec,
    )

    # perm: original output row -> position in the device-order gather;
    # compose with the chunk reorder so one take() restores row order
    perm = np.asarray(csb_output_permutation(row_map, rpd, bm, br))
    final_perm = _chunk_order(n_dev, rpd, bm, bounds)[perm]

    def fn(vals, ridx, cidx, m, n, xp):
        y = shmapped(vals, ridx, cidx, m, n, xp)      # (Bp, D*rpd*bm)
        return jnp.take(y, jnp.asarray(final_perm), axis=1)
    return jax.jit(fn)


def csb_matvec_sharded(
    s: ShardedCSB,
    x: jax.Array,
    *,
    mesh,
    axis_name: str = "model",
    batch_tile: int = 8,
    group: int | None = None,
    interpret: bool | None = None,
    overlap: int | None = None,
) -> jax.Array:
    """y = x @ W^T with W's block-rows spread over ``mesh[axis_name]``.

    ``x``: (..., in_dim), replicated along the model axis (the paper's
    MVM input vector is broadcast to every PEGroup; same here, one
    level up) while the flattened batch dim stays sharded over the
    remaining (data) axes. Returns (..., out_dim) fp32, model-axis
    replicated, batch laid out as the input was.

    ``overlap`` = collective-matmul chunks per device (default 2,
    clamped to the rows available; 1 = the serial compute-then-gather
    pipeline). Results are identical for every value — rows are
    independent — only the compute/collective interleaving changes.
    """
    if axis_name not in tuple(mesh.axis_names):
        raise ValueError(f"mesh has no axis {axis_name!r}: "
                         f"{tuple(mesh.axis_names)}")
    if mesh.shape[axis_name] != s.n_dev:
        raise ValueError(
            f"ShardedCSB was split for {s.n_dev} devices but mesh axis "
            f"{axis_name!r} has {mesh.shape[axis_name]}")
    if interpret is None:
        interpret = default_interpret()
    if group is None:
        group = 1
    if overlap is None:
        overlap = 2
    if overlap < 1:
        raise ValueError(f"overlap must be >= 1, got {overlap}")
    overlap = min(overlap, s.rows_per_dev)

    bc = s.grid[1]
    bn = s.block[1]
    batch_shape = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    b = x2.shape[0]
    # pad so every data-axis shard is a whole number of batch tiles
    dp_total = mesh.size // mesh.shape[axis_name]
    xp = pad_to_grid(x2, batch_tile * dp_total, bc * bn)

    fn = _sharded_fn(mesh, axis_name, s.grid, s.block, s.rows_per_dev,
                     s.row_map, batch_tile, group, interpret, overlap)
    y = fn(s.vals, s.row_idx, s.col_idx, s.m, s.n, xp)
    y = y[:b, : s.shape[0]]
    return y.reshape(*batch_shape, s.shape[0])


__all__ = ["csb_matvec_sharded"]
