"""Mesh-sharded CSB matvec: per-device Pallas kernels + output all-gather.

``csb_matvec_sharded(s, x, mesh=...)`` runs the CSB-MVM kernel on each
device's local block-row shard (a ``ShardedCSB`` built by
``repro.dist.csb_partition``) under ``shard_map``, all-gathers the
per-device output rows along the mesh "model" axis, and permutes the
gathered rows back to the original block-row order (the planner
assigns rows by cycle cost, not contiguously).

Device placement quality is the planner's job; this wrapper executes
whatever ``row_map`` it is handed, exactly as ``csb_mvm_pallas``
executes whatever block layout the engine scheduler chose. Pad rows
(devices with fewer block-rows than the max) carry ``m = n = 0`` and
the kernel masks them to zero, so they cost one grid step but never
corrupt the gather.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.csb_format import ShardedCSB, csb_output_permutation
from .csb_mvm import csb_mvm_pallas, default_interpret
from .ops import pad_to_grid

try:                                      # jax >= 0.6: top-level API
    from jax import shard_map as _shard_map
except ImportError:                       # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map


def _shmap(f, mesh, in_specs, out_specs):
    """shard_map across jax versions: pallas_call has no replication
    rule, so the check must be off — the knob is ``check_rep`` on older
    jax and ``check_vma`` after the rename."""
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
    except TypeError:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)


@functools.lru_cache(maxsize=None)
def _sharded_fn(mesh, axis_name: str, grid: tuple[int, int],
                block: tuple[int, int], rpd: int,
                row_map: tuple[tuple[int, ...], ...],
                batch_tile: int, group: int, interpret: bool):
    """Jitted (shards..., xp) -> gathered-and-unpermuted output, cached
    per static configuration — the sharded twin of ops._run's jit cache,
    so eager serving loops don't re-trace the kernel every call."""
    br, bc = grid
    bm, bn = block
    spec1 = P(axis_name)
    # batch stays sharded over the non-model axes (data parallelism is
    # orthogonal to the block-row split); only the feature/row dims are
    # replicated along the model axis
    dp = tuple(ax for ax in mesh.axis_names if ax != axis_name)
    dp_entry = dp if len(dp) > 1 else (dp[0] if dp else None)
    xspec = P(dp_entry, None)

    # perm: original output row -> position in the device-order gather
    perm = csb_output_permutation(row_map, rpd, bm, br)

    def body(vals, ridx, cidx, m, n, xl):
        # local shard: leading device axis is 1 here — squeeze it
        y = csb_mvm_pallas(
            vals[0], ridx[0], cidx[0], m[0], n[0], xl,
            grid=(rpd, bc), block=(bm, bn), batch_tile=batch_tile,
            group=group, interpret=interpret,
        )                                            # (Bp, rpd*bm)
        return jax.lax.all_gather(y, axis_name, axis=1, tiled=True)

    shmapped = _shmap(
        body, mesh,
        in_specs=(spec1, spec1, spec1, spec1, spec1, xspec),
        out_specs=xspec,
    )

    def fn(vals, ridx, cidx, m, n, xp):
        y = shmapped(vals, ridx, cidx, m, n, xp)      # (Bp, D*rpd*bm)
        return jnp.take(y, jnp.asarray(perm), axis=1)
    return jax.jit(fn)


def csb_matvec_sharded(
    s: ShardedCSB,
    x: jax.Array,
    *,
    mesh,
    axis_name: str = "model",
    batch_tile: int = 8,
    group: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """y = x @ W^T with W's block-rows spread over ``mesh[axis_name]``.

    ``x``: (..., in_dim), replicated along the model axis (the paper's
    MVM input vector is broadcast to every PEGroup; same here, one
    level up) while the flattened batch dim stays sharded over the
    remaining (data) axes. Returns (..., out_dim) fp32, model-axis
    replicated, batch laid out as the input was.
    """
    if axis_name not in tuple(mesh.axis_names):
        raise ValueError(f"mesh has no axis {axis_name!r}: "
                         f"{tuple(mesh.axis_names)}")
    if mesh.shape[axis_name] != s.n_dev:
        raise ValueError(
            f"ShardedCSB was split for {s.n_dev} devices but mesh axis "
            f"{axis_name!r} has {mesh.shape[axis_name]}")
    if interpret is None:
        interpret = default_interpret()
    if group is None:
        group = 1

    bc = s.grid[1]
    bn = s.block[1]
    batch_shape = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    b = x2.shape[0]
    # pad so every data-axis shard is a whole number of batch tiles
    dp_total = mesh.size // mesh.shape[axis_name]
    xp = pad_to_grid(x2, batch_tile * dp_total, bc * bn)

    fn = _sharded_fn(mesh, axis_name, s.grid, s.block, s.rows_per_dev,
                     s.row_map, batch_tile, group, interpret)
    y = fn(s.vals, s.row_idx, s.col_idx, s.m, s.n, xp)
    y = y[:b, : s.shape[0]]
    return y.reshape(*batch_shape, s.shape[0])


__all__ = ["csb_matvec_sharded"]
