"""Pallas TPU kernel for CSB matrix-vector/matrix multiplication.

Computes ``Y = X @ W^T`` where ``W`` is a CSB-pruned matrix held in the
padded device format (`PaddedCSB`): per block a dense kernel matrix
``(Pm, Pn)`` plus within-block survivor indices.

TPU adaptation of the paper's CSB-Engine (DESIGN.md §2):

* The FPGA engine gathers input neurons by ColIdx through a buffer port and
  scatter-accumulates by RowIdx. TPUs have no cheap random access out of
  VMEM, so both indirections become **one-hot matmuls** that run on the
  MXU: ``gather = X_blk @ C^T`` with ``C[l, :] = onehot(col_idx[l])`` and
  ``scatter = Yk @ R`` with ``R[k, :] = onehot(row_idx[k])``.
* inner-block parallelism  -> the (TB, Pn) x (Pn, Pm) kernel matmul;
* inter-block parallelism  -> the grid over block-rows x batch tiles, with
  the block-column dimension folded into a sequential accumulation axis
  (the standard TPU reduction-in-grid pattern);
* the WeightBuffer         -> BlockSpec-staged VMEM tiles.

Workload balance across grid cells is the *scheduler's* job
(engine/schedule.py); this kernel executes whatever block layout it is
handed, masking pad lanes so padded FLOPs never corrupt results.

Grid: ``(batch_tiles, Br, Bc/G)`` — the last axis accumulates into a
VMEM scratch tile (minor-most, so the accumulator stays resident) and
stores the output block once, on the final column step.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.csb_format import PaddedCSB


def _tpu_interpret_available() -> bool:
    """Does this jax expose ``pltpu.force_tpu_interpret_mode``? (landed
    after 0.4.37; the CI golden lane installs a jax that has it)."""
    try:
        from jax.experimental.pallas import tpu as pltpu
    except ImportError:  # pragma: no cover
        return False
    return hasattr(pltpu, "force_tpu_interpret_mode")


def force_tpu_interpret_requested() -> bool:
    """The CI golden lane sets REPRO_FORCE_TPU_INTERPRET=1 so the
    compiled-path branch below is exercised on CPU runners under
    ``pltpu.force_tpu_interpret_mode`` (tests/conftest.py enters it)."""
    return os.environ.get("REPRO_FORCE_TPU_INTERPRET", "0") not in ("", "0")


def default_interpret() -> bool:
    """Interpret-mode default by backend: real accelerators (TPU, GPU)
    compile the kernel; CPU (CI, the container) has no Mosaic/Triton
    target and interprets. The block-column reduction accumulates in a
    kernel *scratch* buffer and stores ``o_ref`` exactly once per output
    tile (no cross-step read-modify-write on the output ref), so the
    kernel no longer depends on TPU's sequential-grid revisit semantics
    and GPU no longer has to stay interpreted.

    Under REPRO_FORCE_TPU_INTERPRET the TPU branch (interpret=False) is
    taken on CPU too, relying on ``force_tpu_interpret_mode`` to emulate
    the Mosaic lowering — the golden lane for the compiled path. On a
    jax too old to have that context manager we stay interpreted rather
    than fail to lower."""
    if force_tpu_interpret_requested() and _tpu_interpret_available():
        return False
    return jax.default_backend() not in ("tpu", "gpu")


def _kernel(x_ref, vals_ref, ridx_ref, cidx_ref, m_ref, n_ref, o_ref,
            acc_ref, *, bm: int, bn: int, group: int):
    """One grid step: TB batch rows x one block-row x G blocks.

    The block-column reduction (grid axis 2) accumulates into the VMEM
    scratch ``acc_ref`` — persistent across grid steps that revisit the
    same output tile — and ``o_ref`` is stored exactly once, on the
    final column step. The output ref is never read, so the kernel does
    not rely on sequential-grid read-modify-write semantics."""
    jc = pl.program_id(2)

    @pl.when(jc == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pm = vals_ref.shape[-2]
    pn = vals_ref.shape[-1]
    acc = acc_ref[...]
    for g in range(group):
        # ---- gather input neurons by ColIdx (one-hot matmul on MXU) ----
        xs = x_ref[:, g * bn:(g + 1) * bn].astype(jnp.float32)   # (TB, bn)
        cidx = cidx_ref[0, g]                                    # (Pn,)
        n_valid = n_ref[0, g]
        lane = jax.lax.broadcasted_iota(jnp.int32, (pn, bn), 1)
        coh = jnp.where(
            (cidx[:, None] == lane)
            & (jax.lax.broadcasted_iota(jnp.int32, (pn, bn), 0)
               < n_valid),
            1.0, 0.0)                                            # (Pn, bn)
        xg = jax.lax.dot_general(
            xs, coh, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)                  # (TB, Pn)

        # ---- dense kernel-matrix MVM (the paper's inner-block work) ----
        kmat = vals_ref[0, g].astype(jnp.float32)                # (Pm, Pn)
        yk = jax.lax.dot_general(
            xg, kmat, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)                  # (TB, Pm)

        # ---- scatter to output rows by RowIdx --------------------------
        ridx = ridx_ref[0, g]                                    # (Pm,)
        m_valid = m_ref[0, g]
        rlane = jax.lax.broadcasted_iota(jnp.int32, (pm, bm), 1)
        roh = jnp.where(
            (ridx[:, None] == rlane)
            & (jax.lax.broadcasted_iota(jnp.int32, (pm, bm), 0)
               < m_valid),
            1.0, 0.0)                                            # (Pm, bm)
        acc = acc + jax.lax.dot_general(
            yk, roh, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                  # (TB, bm)
    acc_ref[...] = acc

    @pl.when(jc == pl.num_programs(2) - 1)
    def _store():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("grid", "block", "batch_tile", "group", "interpret"),
)
def csb_mvm_pallas(
    vals: jax.Array,      # (NB, Pm, Pn)
    row_idx: jax.Array,   # (NB, Pm)
    col_idx: jax.Array,   # (NB, Pn)
    m: jax.Array,         # (NB,)
    n: jax.Array,         # (NB,)
    x: jax.Array,         # (B, Bc*bn) — already padded to the block grid
    *,
    grid: tuple[int, int],
    block: tuple[int, int],
    batch_tile: int = 128,
    group: int = 1,
    interpret: bool | None = None,
) -> jax.Array:
    """Returns (B, Br*bm) fp32. ``group`` = blocks fused per grid step.

    ``interpret=None`` resolves from ``jax.default_backend()``: real
    accelerators compile the kernel, CPU keeps interpret mode."""
    if interpret is None:
        interpret = default_interpret()
    br, bc = grid
    bm, bn = block
    nb, pm, pn = vals.shape
    assert nb == br * bc, (nb, grid)
    assert bc % group == 0, (bc, group)
    b = x.shape[0]
    assert b % batch_tile == 0, (b, batch_tile)

    vals4 = vals.reshape(br, bc, pm, pn)
    ridx3 = row_idx.reshape(br, bc, pm)
    cidx3 = col_idx.reshape(br, bc, pn)
    m2 = m.reshape(br, bc)
    n2 = n.reshape(br, bc)

    gsteps = bc // group
    out = pl.pallas_call(
        functools.partial(_kernel, bm=bm, bn=bn, group=group),
        grid=(b // batch_tile, br, gsteps),
        in_specs=[
            pl.BlockSpec((batch_tile, group * bn),
                         lambda t, i, j: (t, j)),
            pl.BlockSpec((1, group, pm, pn), lambda t, i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, group, pm), lambda t, i, j: (i, j, 0)),
            pl.BlockSpec((1, group, pn), lambda t, i, j: (i, j, 0)),
            pl.BlockSpec((1, group), lambda t, i, j: (i, j)),
            pl.BlockSpec((1, group), lambda t, i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((batch_tile, bm), lambda t, i, j: (t, i)),
        out_shape=jax.ShapeDtypeStruct((b, br * bm), jnp.float32),
        scratch_shapes=[pltpu.VMEM((batch_tile, bm), jnp.float32)],
        interpret=interpret,
    )(x, vals4, ridx3, cidx3, m2, n2)
    return out
