"""Public jit'd wrappers around the Pallas CSB kernels.

``csb_matvec(p, x)`` accepts any leading batch shape (including none — a
single vector, the paper's MVM case), pads batch/feature dims to the
kernel's tile grid and strips the padding off the result.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.csb_format import PaddedCSB
from .csb_mvm import csb_mvm_pallas, default_interpret


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def pad_to_grid(x2: jax.Array, batch_tile: int, in_cols: int) -> jax.Array:
    """Pad a flattened (B, in_dim) batch to the kernel's tile grid:
    batch up to a batch_tile multiple, features up to the block grid's
    ``Bc * bn`` columns. Shared by the local and sharded entry points
    so their padding rules cannot diverge."""
    b = x2.shape[0]
    bp = _round_up(max(b, 1), batch_tile)
    return jnp.pad(x2, ((0, bp - b), (0, in_cols - x2.shape[-1])))


@functools.partial(jax.jit, static_argnames=("batch_tile", "group", "interpret"))
def _run(p: PaddedCSB, x2: jax.Array, batch_tile: int, group: int,
         interpret: bool) -> jax.Array:
    br, bc = p.grid
    bm, bn = p.block
    b = x2.shape[0]
    xp = pad_to_grid(x2, batch_tile, bc * bn)
    y = csb_mvm_pallas(
        p.vals, p.row_idx, p.col_idx, p.m, p.n, xp,
        grid=p.grid, block=p.block, batch_tile=batch_tile, group=group,
        interpret=interpret,
    )
    return y[:b, : p.shape[0]]


def csb_matvec(
    p: PaddedCSB,
    x: jax.Array,
    *,
    batch_tile: int = 8,
    group: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """y = x @ W^T for CSB W;  x: (..., in_dim) -> (..., out_dim) fp32."""
    if interpret is None:
        interpret = default_interpret()
    if group is None:
        group = 1
    batch_shape = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = _run(p, x2, batch_tile, group, interpret)
    return y.reshape(*batch_shape, p.shape[0])
