# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# CSB-RNN's hot spot IS a custom kernel (the CSB-Engine): csb_mvm.py
# holds the Pallas TPU kernel, ops.py the padded public wrapper,
# csb_sharded.py the mesh-sharded entry point, ref.py the jnp oracle,
# paged_attn.py the paged-attention decode kernel the serve path uses.
from .csb_mvm import csb_mvm_pallas, default_interpret
from .csb_sharded import csb_matvec_sharded
from .ops import csb_matvec
from .paged_attn import paged_attn_decode

__all__ = ["csb_matvec", "csb_matvec_sharded", "csb_mvm_pallas",
           "default_interpret", "paged_attn_decode"]
