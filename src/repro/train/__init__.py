"""repro.train — loop, checkpointing, fault tolerance."""
from .loop import StepTimer, TrainConfig, make_train_step, train
from . import checkpoint

__all__ = ["TrainConfig", "make_train_step", "train", "StepTimer",
           "checkpoint"]
