"""Fault-tolerant checkpointing.

Layout: ``<dir>/step_<N>/`` holding one ``.npz`` of flattened leaves plus
``manifest.json`` (tree structure, dtypes, shapes, content hashes).
Commit is atomic: everything is written into ``step_<N>.tmp`` and
renamed; a crash mid-save never corrupts the latest checkpoint.
``restore`` re-sharding is elastic — arrays are saved unsharded (single
host) and ``device_put`` against whatever mesh/shardings the restarted
job uses, so pod-count changes between runs are fine.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _path_str(treedef) -> str:
    return str(treedef)


def save(ckpt_dir: str, step: int, tree: PyTree,
         extra: dict | None = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = _flatten(tree)
    arrays = {}
    for i, x in enumerate(leaves):
        a = np.asarray(x)
        if a.dtype.kind == "V" or "bfloat16" in str(a.dtype):
            # npz has no bfloat16 — store the lossless fp32 upcast; the
            # manifest keeps the logical dtype and restore re-casts.
            a = a.astype(np.float32)
        arrays[f"leaf_{i}"] = a
    npz_path = os.path.join(tmp, "arrays.npz")
    np.savez(npz_path, **arrays)

    hashes = {k: hashlib.sha256(v.tobytes()).hexdigest()[:16]
              for k, v in arrays.items()}
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": _path_str(treedef),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "hashes": hashes,
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: PyTree,
            shardings: PyTree | None = None,
            verify: bool = True) -> tuple[PyTree, dict]:
    """``like`` supplies the tree structure (abstract or concrete)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten(like)
    if manifest["n_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, "
            f"model expects {len(leaves)} — incompatible trees")
    out = []
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    for i, (ref, sh) in enumerate(zip(leaves, shard_leaves)):
        arr = data[f"leaf_{i}"]
        if verify:
            h = hashlib.sha256(arr.tobytes()).hexdigest()[:16]
            if h != manifest["hashes"][f"leaf_{i}"]:
                raise IOError(f"checkpoint leaf_{i} hash mismatch "
                              f"(corrupt checkpoint)")
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"leaf_{i} shape {arr.shape} != {ref.shape}")
        jarr = jax.numpy.asarray(arr).astype(ref.dtype)  # handles bf16
        out.append(jax.device_put(jarr, sh) if sh is not None else jarr)
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


def restore_latest(ckpt_dir: str, like: PyTree,
                   shardings: PyTree | None = None):
    step = latest_step(ckpt_dir)
    if step is None:
        return None
    tree, extra = restore(ckpt_dir, step, like, shardings)
    return step, tree, extra


def keep_last(ckpt_dir: str, n: int = 3) -> None:
    """Garbage-collect all but the newest n checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-n]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
