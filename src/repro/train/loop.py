"""The training loop: microbatched grad accumulation, ADMM-CSB hooks,
checkpoint/auto-resume, step-time straggler telemetry.

``make_train_step`` builds a single jitted step:
  grads = mean over microbatches of d(loss + admm_penalty)/d(params)
  grads = compress->decompress(grads)   (optional int8 error feedback)
  grads = clip(psum'd grads)            (DP mean comes from sharding)
  params, opt = optimizer.update(...)
With ``TrainConfig.compress_grads`` the int8 error-feedback gradient
compressor (``dist.compress``) sits where the DP all-reduce runs: the
optimizer only ever sees the dequantized wire gradient, and the
per-leaf quantization residual is carried in the train step's state so
the transmitted sum tracks the true sum (EF-SGD). ~4x all-reduce
traffic reduction; the dry-run train records carry the projected byte
counts (``collectives.grad_compress``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import admm_init, admm_penalty, admm_update, admm_finalize
from repro.dist.compress import compress, compress_init, decompress
from repro.obs import metrics as obs_metrics, trace as obs_trace
from repro.optim import clip_by_global_norm, get_optimizer
from . import checkpoint as ckpt

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    weight_decay: float = 0.0
    clip_norm: float = 1.0
    optimizer: str = "adamw"
    microbatches: int = 1
    steps: int = 100
    log_every: int = 10
    ckpt_dir: str | None = None
    ckpt_every: int = 100
    keep_ckpts: int = 3
    # ADMM-CSB pruning
    admm_rho: float = 1e-3
    admm_every: int = 0          # 0 = disabled; else projection period
    # int8 error-feedback gradient compression on the DP all-reduce
    compress_grads: bool = False


def make_train_step(
    loss_fn: Callable[[PyTree, dict], jax.Array],
    tcfg: TrainConfig,
    lr_schedule: Callable | None = None,
    csb_specs: PyTree | None = None,
    donate: bool = True,
):
    """Returns (step_fn, opt) where
    step_fn(params, opt_state, admm_state, residual, batch, step) ->
        (params, opt_state, admm_state, residual, metrics).

    ``residual`` is the error-feedback carry for
    ``tcfg.compress_grads`` (init with ``dist.compress_init(params)``);
    pass None when compression is off — the step then never touches it.
    """
    opt = get_optimizer(tcfg.optimizer)
    sched = lr_schedule or (lambda s: jnp.asarray(tcfg.lr, jnp.float32))

    def total_loss(params, batch, admm_state):
        loss = loss_fn(params, batch)
        if csb_specs is not None and admm_state is not None:
            loss = loss + admm_penalty(params, admm_state, csb_specs)
        return loss

    def step_fn(params, opt_state, admm_state, residual, batch, step):
        if tcfg.microbatches > 1:
            def micro(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(total_loss)(params, mb, admm_state)
                gsum = jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                    gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree.map(
                lambda x: x.reshape(tcfg.microbatches,
                                    x.shape[0] // tcfg.microbatches,
                                    *x.shape[1:]),
                batch)
            (gsum, lsum), _ = jax.lax.scan(micro, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, gsum)
            loss = lsum / tcfg.microbatches
        else:
            loss, grads = jax.value_and_grad(total_loss)(
                params, batch, admm_state)

        if residual is not None:
            # the wire stage of the DP all-reduce: quantize to int8 on a
            # per-leaf grid, carry the round-off into the next step
            comp, residual = compress(grads, residual)
            grads = decompress(comp)

        if tcfg.clip_norm:
            grads = clip_by_global_norm(grads, tcfg.clip_norm)
        lr = sched(step)
        params, opt_state = opt.update(grads, opt_state, params, lr,
                                       tcfg.weight_decay)
        metrics = {"loss": loss, "lr": lr}
        return params, opt_state, admm_state, residual, metrics

    jitted = jax.jit(step_fn, donate_argnums=(0, 1, 3) if donate else ())
    return jitted, opt


@dataclasses.dataclass
class StepTimer:
    """Straggler telemetry: wall-time quantiles over a sliding window."""

    window: int = 100

    def __post_init__(self):
        self.times: list[float] = []

    def record(self, dt: float):
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)

    def quantiles(self):
        if not self.times:
            return {}
        a = np.asarray(self.times)
        return {"p50": float(np.percentile(a, 50)),
                "p95": float(np.percentile(a, 95)),
                "p99": float(np.percentile(a, 99))}

    def is_straggling(self, dt: float, factor: float = 3.0) -> bool:
        q = self.quantiles()
        return bool(q) and dt > factor * q["p50"]


def train(
    loss_fn: Callable,
    params: PyTree,
    batches,                     # iterator of (step, batch)
    tcfg: TrainConfig,
    lr_schedule=None,
    csb_specs: PyTree | None = None,
    eval_fn: Callable | None = None,
    log: Callable[[str], None] = print,
):
    """Run the loop with auto-resume + periodic checkpoints.

    Returns (params, history).
    """
    step_fn, opt = make_train_step(loss_fn, tcfg, lr_schedule, csb_specs)
    opt_state = opt.init(params)
    admm_state = (admm_init(params, csb_specs, tcfg.admm_rho)
                  if csb_specs is not None else None)
    residual = compress_init(params) if tcfg.compress_grads else None
    start = 0

    def _ckpt_tree():
        # the EF residual is train state: dropping it on resume would
        # break the transmitted-sum-tracks-true-sum guarantee right at
        # the restart boundary
        tree = {"params": params, "opt": opt_state}
        if residual is not None:
            tree["residual"] = residual
        return tree

    if tcfg.ckpt_dir:
        try:
            got = ckpt.restore_latest(tcfg.ckpt_dir, _ckpt_tree())
        except ValueError:
            if residual is None:
                raise
            # checkpoints predate compress_grads being switched on:
            # restore what exists and start the EF carry from zero
            got = ckpt.restore_latest(
                tcfg.ckpt_dir, {"params": params, "opt": opt_state})
            log("[resume] checkpoint has no EF residual; starting the "
                "compression carry from zero")
        if got is not None:
            start, tree, extra = got
            params, opt_state = tree["params"], tree["opt"]
            residual = tree.get("residual", residual)
            log(f"[resume] restored step {start} from {tcfg.ckpt_dir}")

    timer = StepTimer()
    history = []
    for step, batch in batches:
        if step < start:
            continue
        if step >= tcfg.steps:
            break
        t0 = time.perf_counter()
        t0_ns = time.perf_counter_ns()
        params, opt_state, admm_state, residual, metrics = step_fn(
            params, opt_state, admm_state, residual, batch,
            jnp.asarray(step))
        if (csb_specs is not None and tcfg.admm_every
                and (step + 1) % tcfg.admm_every == 0):
            admm_state = admm_update(params, admm_state, csb_specs)
        loss = float(metrics["loss"])   # blocks: the step's true wall
        dt = time.perf_counter() - t0
        timer.record(dt)
        tr = obs_trace.get()
        if tr is not None:
            tr.complete("train/step", t0_ns, int(dt * 1e9),
                        track="train", args={"step": step, "loss": loss})
        reg = obs_metrics.get()
        if reg is not None:
            reg.histogram("train/step/wall_us").observe(dt * 1e6)
            reg.gauge("train/step/loss").set(loss)
        history.append({"step": step, "loss": loss, "dt": dt})
        if step % tcfg.log_every == 0:
            q = timer.quantiles()
            log(f"step {step:5d} loss {loss:.4f} "
                f"dt {dt*1e3:.1f}ms p95 {q.get('p95', 0)*1e3:.1f}ms"
                + (" STRAGGLER" if timer.is_straggling(dt) else ""))
        if tcfg.ckpt_dir and (step + 1) % tcfg.ckpt_every == 0:
            ckpt.save(tcfg.ckpt_dir, step + 1, _ckpt_tree())
            ckpt.keep_last(tcfg.ckpt_dir, tcfg.keep_ckpts)

    if csb_specs is not None:
        params = admm_finalize(params, csb_specs)
    return params, history
