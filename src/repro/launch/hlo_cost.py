"""Loop-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE — a
program built around ``lax.scan`` (layers, attention chunks, microbatches)
under-reports FLOPs by the trip count. This module re-derives FLOPs /
HBM bytes / collective bytes from the optimized HLO text, multiplying
loop bodies by their ``known_trip_count`` backend annotation.

Accounting model (mirrors XLA's HloCostAnalysis):
  - dot: 2 * prod(output dims) * prod(lhs contracting dims)
  - elementwise arithmetic/transcendental: 1 flop per output element
  - reduce: 1 flop per *input* element
  - bytes: per top-level op, operand bytes + output bytes; fusion
    internals contribute flops but NOT bytes (they live in registers/VMEM)
  - while: body+condition cost x trip count
  - collectives: operand bytes, x trip count when loop-resident;
    async -start/-done pairs counted once
"""
from __future__ import annotations

import dataclasses
import re
from functools import lru_cache

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "tanh", "log", "rsqrt", "sqrt", "power", "negate",
    "abs", "floor", "ceil", "sign", "cosine", "sine", "logistic",
    "exponential-minus-one", "log-plus-one", "atan2", "remainder",
    "and", "or", "xor", "not", "select", "clamp", "compare",
}

_ZERO_BYTE_OPS = {
    "tuple", "get-tuple-element", "bitcast", "parameter", "constant",
    "after-all", "partition-id", "replica-id", "iota", "copy-start",
    "copy-done",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(([^)]*)\)(.*)$")
_COMP_HDR_RE = re.compile(
    r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->\s*.+\s*\{\s*$")
_NAME_REF_RE = re.compile(r"%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"?n\\?"?:\\?"?(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_info(type_str: str) -> tuple[int, int]:
    """(total elements, total bytes) of a possibly-tuple type string."""
    elems = 0
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


def _first_shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: dict | None = None
    coll_count: dict | None = None

    def __post_init__(self):
        self.coll_bytes = self.coll_bytes or {}
        self.coll_count = self.coll_count or {}

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0) + v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0) + v * mult

    @property
    def collective_total(self) -> float:
        return sum(self.coll_bytes.values())


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    operands: str
    attrs: str


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: dict[str, list[_Op]] = {}
        self.entry: str | None = None
        self._parse(hlo_text)
        self._memo: dict[tuple[str, bool], Cost] = {}

    # -- parsing -----------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur: list[_Op] | None = None
        cur_name = None
        for line in text.splitlines():
            hdr = _COMP_HDR_RE.match(line)
            if hdr:
                cur_name = hdr.group(1)
                cur = []
                self.computations[cur_name] = cur
                if line.startswith("ENTRY"):
                    self.entry = cur_name
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            m = _OP_RE.match(line)
            if m:
                cur.append(_Op(*m.groups()))
        if self.entry is None and self.computations:
            # fall back: the last computation is usually the entry
            self.entry = list(self.computations)[-1]

    # -- per-op costs --------------------------------------------------------
    def _op_flops(self, op: _Op) -> float:
        out_elems, _ = _shape_info(op.type_str)
        if op.opcode == "dot":
            cm = _CONTRACT_RE.search(op.attrs)
            # resolve lhs shape: first operand
            first = _NAME_REF_RE.search(op.operands)
            contract = 1
            if cm and first:
                lhs_dims_idx = [int(d) for d in cm.group(1).split(",") if d]
                lhs_shape = self._operand_dims.get(first.group(1), [])
                for i in lhs_dims_idx:
                    if i < len(lhs_shape):
                        contract *= lhs_shape[i]
            return 2.0 * out_elems * contract
        if op.opcode == "convolution":
            return 2.0 * out_elems  # no convs in this codebase; nominal
        if op.opcode in _ELEMENTWISE:
            return float(out_elems)
        if op.opcode == "reduce":
            # ~1 flop per input element
            first = _NAME_REF_RE.search(op.operands)
            if first:
                dims = self._operand_dims.get(first.group(1), [])
                n = 1
                for d in dims:
                    n *= d
                return float(n)
            return float(out_elems)
        return 0.0

    def _op_bytes(self, op: _Op, defs: dict[str, int]) -> float:
        if op.opcode in _ZERO_BYTE_OPS:
            return 0.0
        _, out_bytes = _shape_info(op.type_str)
        # slicing reads only what it produces — charging the full operand
        # would bill a scanned weight stack once PER LAYER (9.7 GB of
        # phantom traffic on mamba2 decode; §Perf iter log).
        if op.opcode in ("dynamic-slice", "slice", "gather"):
            return 2.0 * out_bytes
        if op.opcode in ("dynamic-update-slice", "scatter"):
            ops_ = _NAME_REF_RE.findall(op.operands)
            upd = defs.get(ops_[1], out_bytes) if len(ops_) > 1 else out_bytes
            return 2.0 * upd
        total = float(out_bytes)
        for m in _NAME_REF_RE.finditer(op.operands):
            total += defs.get(m.group(1), 0)
        return total

    # -- computation walk ----------------------------------------------------
    def cost_of(self, comp_name: str, count_bytes: bool = True) -> Cost:
        key = (comp_name, count_bytes)
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = Cost()  # cycle guard
        ops = self.computations.get(comp_name, [])
        defs: dict[str, int] = {}
        dims: dict[str, list[int]] = {}
        for op in ops:
            _, b = _shape_info(op.type_str)
            defs[op.name] = b
            dims[op.name] = _first_shape_dims(op.type_str)
        self._operand_dims = dims

        total = Cost()
        for op in ops:
            oc = op.opcode
            if oc == "while":
                trips = [int(t) for t in _TRIP_RE.findall(op.attrs)]
                trip = trips[0] if trips else 1
                bm = _BODY_RE.search(op.attrs)
                cm = _COND_RE.search(op.attrs)
                if bm:
                    total.add(self.cost_of(bm.group(1), count_bytes), trip)
                if cm:
                    total.add(self.cost_of(cm.group(1), count_bytes), trip)
                continue
            if oc in ("fusion", "call", "async-start", "custom-call"):
                cm = _CALLS_RE.search(op.attrs)
                if cm:
                    # fusion internals: flops yes, HBM bytes no
                    total.add(self.cost_of(cm.group(1), False), 1.0)
                if count_bytes:
                    total.bytes += self._op_bytes(op, defs)
                continue
            if oc == "conditional":
                for cm in _NAME_REF_RE.finditer(op.attrs):
                    nm = cm.group(1)
                    if nm in self.computations:
                        total.add(self.cost_of(nm, count_bytes), 1.0)
                continue
            hit = next((c for c in COLLECTIVE_OPS if oc.startswith(c)), None)
            if hit is not None:
                if not oc.endswith("-done"):
                    size = 0.0
                    for m in _NAME_REF_RE.finditer(op.operands):
                        size += defs.get(m.group(1), 0)
                    total.coll_bytes[hit] = total.coll_bytes.get(hit, 0) + size
                    total.coll_count[hit] = total.coll_count.get(hit, 0) + 1
                    if count_bytes:
                        total.bytes += self._op_bytes(op, defs)
                continue
            # plain op
            self._operand_dims = dims
            total.flops += self._op_flops(op)
            if count_bytes:
                total.bytes += self._op_bytes(op, defs)
        self._memo[key] = total
        return total

    def module_cost(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.cost_of(self.entry, True)


def analyze(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).module_cost()


_CONVERT_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*f32\[([\d,]+)\][^=]*?"
    r"(convert|copy)\(", re.M)


def f32_convert_overhead(hlo_text: str, min_bytes: int = 64 << 20) -> int:
    """Bytes of large top-level f32 convert/copy buffers.

    XLA:CPU lowers bf16 dot operands via f32 converts and hoists them out
    of loops — buffers a TPU build would never allocate. Their total
    (double-count-prone upper bound) lets EXPERIMENTS.md report a
    TPU-adjusted peak-memory estimate next to the measured CPU value.
    """
    total = 0
    for m in _CONVERT_RE.finditer(hlo_text):
        n = 1
        for d in m.group(1).split(","):
            if d:
                n *= int(d)
        b = n * 4
        if b >= min_bytes:
            total += b
    return total
