"""Three-term roofline analysis from the compiled dry-run artifact.

    compute    = HLO_FLOPs_per_device        / peak_FLOP/s per chip
    memory     = HLO_bytes_per_device        / HBM bandwidth per chip
    collective = collective operand bytes    / (links x link bandwidth)

``cost_analysis`` of an SPMD-compiled module reports *per-device* FLOPs
and bytes, so dividing by per-chip peaks matches the assignment's
``total / (chips x peak)`` formula. Collective bytes are not in
cost_analysis — we parse the optimized HLO, resolving each collective
op's operand shapes through a def-table so sizes are the true *operand*
sizes (an all-gather's input, not its blown-up output).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (one link active per collective phase, conservative).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# `%name = f32[1,2]{1,0} opcode(%a, %b), attrs...` — the type may be a
# tuple `(f32[..]{..}, u32[])` and may carry layout braces.
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+"
    r"([\w\-]+)\(([^)]*)\)", re.M)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%?([\w\.\-]+)")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: dict[str, int]
    count_by_op: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of every collective op in the (S)HLO text.

    Loop bodies are counted once per textual occurrence; scanned-layer
    programs therefore under-report by the trip count — callers should
    multiply while-loop-resident collectives by the known layer count
    when exactness matters (we report both raw and corrected values).
    """
    defs: dict[str, int] = {}
    pending: list[tuple[str, str]] = []
    for m in _DEF_RE.finditer(hlo_text):
        name, type_str, opcode, operands = m.groups()
        defs[name] = _shape_bytes(type_str)
        if any(opcode.startswith(c) for c in COLLECTIVE_OPS):
            canon = next(c for c in COLLECTIVE_OPS if opcode.startswith(c))
            if opcode.endswith("-done"):
                continue  # async pair: the -start op carries the operands
            pending.append((canon, operands))

    bytes_by_op: dict[str, int] = {}
    count_by_op: dict[str, int] = {}
    for canon, operands in pending:
        size = 0
        for om in _OPERAND_RE.finditer(operands):
            size += defs.get(om.group(1), 0)
        bytes_by_op[canon] = bytes_by_op.get(canon, 0) + size
        count_by_op[canon] = count_by_op.get(canon, 0) + 1
    return CollectiveStats(bytes_by_op, count_by_op)


def loop_trip_counts(hlo_text: str) -> list[int]:
    """Best-effort known trip counts of while loops in the module."""
    out = []
    for m in re.finditer(r'known_trip_count=\{?"?n"?[:=]\s*"?(\d+)"?', hlo_text):
        out.append(int(m.group(1)))
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops_per_device: float
    useful_ratio: float

    def as_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


def roofline(flops_per_device: float, bytes_per_device: float,
             collective_bytes: float,
             model_flops_per_device: float) -> Roofline:
    t_c = flops_per_device / PEAK_FLOPS
    t_m = bytes_per_device / HBM_BW
    t_x = collective_bytes / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    ratio = (model_flops_per_device / flops_per_device
             if flops_per_device else 0.0)
    return Roofline(
        flops_per_device=flops_per_device,
        bytes_per_device=bytes_per_device,
        collective_bytes=collective_bytes,
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        dominant=dominant,
        model_flops_per_device=model_flops_per_device,
        useful_ratio=ratio,
    )


def model_flops(cfg, shape, chips: int) -> float:
    """Analytic 'useful' FLOPs per device: 6*N_active*D for train,
    2*N_active*D for inference cells (fwd only)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.tokens
        mult = 6.0
    elif shape.kind == "prefill":
        tokens = shape.tokens
        mult = 2.0
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        mult = 2.0
    return mult * n_active * tokens / chips
