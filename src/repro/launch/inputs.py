"""ShapeDtypeStruct stand-ins for every model input (no allocation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import abstract_cache
from repro.models.config import ModelConfig, ShapeConfig

I32 = jnp.int32


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract input batch for the given cell. kind-dependent:

    train   -> {tokens, labels [, img_embeds]}
    prefill -> {tokens [, img_embeds]}
    decode  -> {tokens(B,1), cache, pos}
    """
    gb, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct

    def tok(b, length):
        if cfg.n_codebooks:
            return sds((b, length, cfg.n_codebooks), I32)
        return sds((b, length), I32)

    if shape.kind == "decode":
        # per-slot positions: the serve scheduler refills freed slots
        # mid-decode, so the production decode step carries a (B,) pos
        # vector rather than one scalar depth for the whole batch
        return {
            "tokens": tok(gb, 1),
            "cache": abstract_cache(cfg, gb, s, jnp.dtype(cfg.dtype)),
            "pos": sds((gb,), I32),
        }

    text_len = s - cfg.n_img_tokens if cfg.n_img_tokens else s
    batch = {"tokens": tok(gb, text_len)}
    if cfg.n_img_tokens:
        batch["img_embeds"] = sds((gb, cfg.n_img_tokens, 1024),
                                  jnp.dtype(cfg.dtype))
    if shape.kind == "train":
        batch["labels"] = tok(gb, text_len)
    return batch
