"""repro.launch — mesh, dry-run, roofline, training entrypoints.

NOTE: ``dryrun`` is intentionally NOT imported here — it sets XLA_FLAGS
for 512 host devices at import time and must only be imported as the
process entrypoint (``python -m repro.launch.dryrun``).
"""
from .mesh import make_production_mesh, make_test_mesh
from . import roofline

__all__ = ["make_production_mesh", "make_test_mesh", "roofline"]
