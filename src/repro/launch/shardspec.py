"""Sharding-spec derivation for optimizer states and step signatures."""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.optim.optimizers import Optimizer, OptState

PyTree = Any

_isp = lambda x: isinstance(x, P)


def opt_state_specs(opt: Optimizer, param_specs: PyTree) -> OptState:
    """PartitionSpec tree shaped like opt.init(params)'s output."""
    if opt.name == "sgd":
        inner = param_specs
    elif opt.name == "adamw":
        inner = {"m": param_specs, "v": param_specs}
    elif opt.name == "adafactor":
        def one(spec: P):
            parts = tuple(spec)
            if len(parts) >= 2:
                return {"r": P(*parts[:-1]),
                        "c": P(*(parts[:-2] + parts[-1:]))}
            return {"v": spec}

        inner = jax.tree.map(one, param_specs, is_leaf=_isp)
    else:  # pragma: no cover
        raise ValueError(opt.name)
    return OptState(inner=inner, step=P())


def to_named(mesh, tree: PyTree) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=_isp)
