"""Distributed training entrypoint.

Builds the mesh from the real device set (any shape that fits — the
production 16x16 needs real hardware; on one host it degrades to a 1x1
mesh), pins param/opt shardings from repro.dist rules, and runs the
fault-tolerant training loop on synthetic char-LM data.

  python -m repro.launch.train --arch gemma-2b --reduced --steps 50
  python -m repro.launch.train --arch qwen3-32b --mesh 16x16 \
      --steps 1000 --ckpt /ckpts/qwen3   # on a real pod

``--reduced`` uses the smoke-scale config (CPU-feasible); otherwise the
full assigned config is instantiated (requires the memory of a real pod).
"""
from __future__ import annotations

import argparse
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.data import CharLMTask, lm_batch_iterator, sharded_batches
from repro.dist import (
    ShardingPolicy, activation_rules, batch_specs, param_specs, use_rules,
)
from repro.launch.shardspec import to_named
from repro.models import forward_loss, init_params
from repro.optim import linear_warmup_cosine
from repro.train import TrainConfig, train


def make_mesh(spec: str | None) -> Mesh:
    devs = jax.devices()
    if spec:
        dims = tuple(int(x) for x in spec.split("x"))
    else:
        dims = (len(devs), 1)
    need = math.prod(dims)
    if need > len(devs):
        raise SystemExit(f"mesh {dims} needs {need} devices, "
                         f"have {len(devs)}")
    axes = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
    return Mesh(np.asarray(devs[:need]).reshape(dims), axes)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default=None, help="e.g. 16x16 or 2x16x16")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced and cfg.n_img_tokens:
        args.seq = max(args.seq, cfg.n_img_tokens + 32)
    mesh = make_mesh(args.mesh)
    policy = ShardingPolicy(fsdp=cfg.param_count() > 3e10)
    rules = activation_rules(cfg, mesh, policy, global_batch=args.batch)
    print(f"arch={cfg.name} params={cfg.param_count():,} "
          f"mesh={dict(mesh.shape)}")

    with use_rules(rules):
        params = init_params(jax.random.PRNGKey(0), cfg)
        pspecs = param_specs(cfg, params, mesh, policy)
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            params, pspecs, is_leaf=lambda x: isinstance(x, P))

        task = CharLMTask(vocab=min(cfg.vocab, 256), seed=0)
        bspecs = batch_specs(cfg, "train", mesh, global_batch=args.batch)
        batches = sharded_batches(
            lm_batch_iterator(task, args.batch, args.seq), mesh, bspecs)

        tcfg = TrainConfig(lr=args.lr, steps=args.steps, log_every=10,
                           ckpt_dir=args.ckpt, ckpt_every=50)
        sched = linear_warmup_cosine(args.lr, warmup=10, steps=args.steps)

        def loss_fn(p, b):
            b = dict(b)
            if cfg.n_img_tokens:
                b["img_embeds"] = jnp.zeros(
                    (b["tokens"].shape[0], cfg.n_img_tokens, 1024),
                    jnp.dtype(cfg.dtype))
            if cfg.n_codebooks:
                b["tokens"] = jnp.repeat(
                    b["tokens"][..., None], cfg.n_codebooks, -1)
                b["labels"] = jnp.repeat(
                    b["labels"][..., None], cfg.n_codebooks, -1)
            return forward_loss(p, b, cfg)

        params, history = train(loss_fn, params, batches, tcfg,
                                lr_schedule=sched)
    if history:
        print(f"final loss {history[-1]['loss']:.4f} "
              f"({len(history)} steps)")


if __name__ == "__main__":
    main()
