"""Production mesh builders (as FUNCTIONS — importing this module never
touches jax device state).

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is pure data parallelism across pods (slower inter-pod links),
so gradients cross pods once per step while model collectives stay inside
a pod.
"""
from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devs)} — the dry-run entrypoint "
            "must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax")
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Tiny host-device mesh for CI tests (requires >= prod(shape) devs)."""
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(f"need {n} devices for test mesh")
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes)
