import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the full step function (train_step /
prefill_step / serve_step), pins param/opt/batch/cache shardings, lowers
against ShapeDtypeStruct inputs (zero allocation), compiles for the
production mesh, and records:

  - memory_analysis()      (proves the program fits per device)
  - cost_analysis()        (per-device FLOPs / bytes for the roofline)
  - collective operand bytes parsed from the optimized HLO
  - the derived three-term roofline

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --sweep            # all runnable cells
  python -m repro.launch.dryrun --list             # show the 40-cell grid

Results land in benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (
    ARCH_IDS, all_cells, cell_is_runnable, get_config, shape_overrides,
    sharding_policy, train_microbatches,
)
from repro.dist import (
    activation_rules, batch_specs, cache_specs, param_specs, use_rules,
)
from repro.launch.inputs import input_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (
    Roofline, loop_trip_counts, model_flops, parse_collectives, roofline,
)
from repro.launch.shardspec import opt_state_specs, to_named
from repro.models import abstract_params, forward_loss, prefill
from repro.models import decode_step as model_decode_step
from repro.models.config import SHAPES
from repro.optim import clip_by_global_norm, get_optimizer

RESULTS_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))),
    "benchmarks", "results", "dryrun")


def build_cell(arch: str, shape_name: str, mesh, extra_over=None,
               policy=None, micro: int | None = None,
               accum_dtype=None):
    """Returns (step_fn, args, in_shardings, donate, rules, cfg)."""
    over = shape_overrides(arch, shape_name)
    over.update(extra_over or {})
    cfg = dataclasses.replace(get_config(arch), **over)
    shp = SHAPES[shape_name]
    policy = policy or sharding_policy(arch, shape_name)
    rules = activation_rules(cfg, mesh, policy,
                             global_batch=shp.global_batch)

    aparams = abstract_params(cfg)
    pspecs = param_specs(cfg, aparams, mesh, policy)
    psh = to_named(mesh, pspecs)
    bspec = batch_specs(cfg, shp.kind, mesh, global_batch=shp.global_batch)
    window = cfg.window

    if shp.kind == "train":
        micro = micro or train_microbatches(arch)
        dp_total = 1
        for ax in ("pod", "data"):
            if ax in mesh.axis_names:
                dp_total *= mesh.shape[ax]
        micro = max(1, min(micro, shp.global_batch // dp_total))
        # FSDP cells accumulate grads in bf16 (halves the accumulation
        # buffer; grads are bf16 anyway — §Perf iter log)
        if accum_dtype is None:
            accum_dtype = jnp.bfloat16 if policy.fsdp else jnp.float32
        opt_name = "adafactor" if cfg.param_count() > 1e11 else "adamw"
        opt = get_optimizer(opt_name)
        astate = jax.eval_shape(opt.init, aparams)
        osh = to_named(mesh, opt_state_specs(opt, pspecs))

        def constrain(tree):
            return jax.tree.map(
                lambda t, sh: jax.lax.with_sharding_constraint(t, sh),
                tree, psh)

        def train_step(params, opt_state, batch):
            def loss_fn(p, b):
                return forward_loss(p, b, cfg, window=window)

            if micro > 1:
                def micro_step(carry, mb):
                    gsum, lsum = carry
                    l, g = jax.value_and_grad(loss_fn)(params, mb)
                    g = constrain(g)
                    gsum = jax.tree.map(
                        lambda a, b_: a + b_.astype(a.dtype), gsum, g)
                    return (constrain(gsum), lsum + l), None

                g0 = constrain(jax.tree.map(
                    lambda pp: jnp.zeros(pp.shape, accum_dtype), params))
                mbs = jax.tree.map(
                    lambda x: x.reshape(
                        micro, x.shape[0] // micro, *x.shape[1:]), batch)
                (gsum, lsum), _ = jax.lax.scan(micro_step, (g0, 0.0), mbs)
                grads = jax.tree.map(lambda g: g / micro, gsum)
                loss = lsum / micro
            else:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
                grads = constrain(grads)
            grads = clip_by_global_norm(grads, 1.0)
            params, opt_state = opt.update(grads, opt_state, params,
                                           jnp.asarray(3e-4), 0.1)
            return params, opt_state, loss

        batch = input_specs(cfg, shp)
        bsh = {k: NamedSharding(mesh, bspec.get(k, P()))
               for k in batch}
        args = (aparams, astate, batch)
        in_sh = (psh, osh, bsh)
        return train_step, args, in_sh, (0, 1), rules, cfg

    if shp.kind == "prefill":
        def prefill_step(params, batch):
            return prefill(params, batch, cfg, window=window)

        batch = input_specs(cfg, shp)
        bsh = {k: NamedSharding(mesh, bspec.get(k, P())) for k in batch}
        return prefill_step, (aparams, batch), (psh, bsh), (), rules, cfg

    # decode — the continuous-batching serve step: (B,) per-slot
    # positions, tokens/pos/cache sharded over the data (replica) axes
    ins = input_specs(cfg, shp)
    csh = to_named(mesh, cache_specs(cfg, ins["cache"], mesh, policy))
    tok_sh = NamedSharding(mesh, bspec["tokens"])

    def serve_step(params, cache, tokens, pos):
        return model_decode_step(params, cache, tokens, pos, cfg)

    args = (aparams, ins["cache"], ins["tokens"], ins["pos"])
    in_sh = (psh, csh, tok_sh, NamedSharding(mesh, bspec.get("pos", P())))
    return serve_step, args, in_sh, (1,), rules, cfg


def csb_partition_report(cfg, mesh, bm: int = 64) -> dict:
    """Per-device cycle-balance the CSB block partitioner achieves on
    this cell's mesh (paper §5.2 lifted to chips).

    The cell's own weights are dense ShapeDtypeStructs (nothing is
    allocated in a dry run), so the block survivor grid is synthesized
    to the paper's skew profile deterministically per arch: stacked
    gate bands with very different survivor densities (pruned LSTM
    gates keep wildly different fractions — the workload variance of
    Fig. 7b) plus a dense diagonal band (§6.3.2). Reported: greedy vs
    naive-equal max/mean imbalance over the "model" axis, the quantity
    the sharded kernel's critical path follows.
    """
    from repro.dist.csb_partition import block_row_cycles, plan_block_rows

    n_dev = int(mesh.shape["model"])
    d = int(cfg.d_model)
    # refine blocks until each device owns >= 4 block-rows — with fewer
    # the placement has no freedom and any policy hits the single-row
    # imbalance floor
    while bm > 8 and d // bm < 4 * n_dev:
        bm //= 2
    br = bc = max(d // bm, n_dev)
    rng = np.random.default_rng(d * 31 + bm)
    # per-row survivor fraction: 4 gate bands (dense -> heavily pruned),
    # lognormal jitter within a band
    gate = np.array([1.0, 0.45, 0.2, 0.1])[
        (np.arange(br) * 4) // br]                       # (Br,)
    frac = np.clip(gate * rng.lognormal(0.0, 0.25, br), 4 / bm, 1.0)
    m = np.clip((frac[:, None] * bm
                 * rng.uniform(0.7, 1.3, (br, bc))).astype(np.int64),
                2, bm)
    n = np.clip(rng.integers(bm // 4, bm // 2, size=(br, bc)), 2, bm)
    band = np.abs(np.arange(br)[:, None] - np.arange(bc)[None, :]) <= 1
    m = np.where(band, bm, m)
    n = np.where(band, bm, n)
    cyc = block_row_cycles((m, n))
    greedy = plan_block_rows(cyc, n_dev, policy="greedy")
    equal = plan_block_rows(cyc, n_dev, policy="equal")
    return {
        "block": bm, "grid": [int(br), int(bc)], "model_devices": n_dev,
        "greedy": greedy.as_dict(), "equal": equal.as_dict(),
        "speedup_vs_equal": round(
            max(equal.device_cycles) / max(max(greedy.device_cycles), 1),
            3),
    }


def serve_report(cfg, shp, rl, chips: int, page_size: int = 64) -> dict:
    """Continuous-batching serving projection for a decode cell.

    Occupancy comes from replaying the real admission policy
    (``serve.scheduler.simulate_admission``) over a deterministic
    mixed-length trace (3 waves of requests, generation lengths spread
    4x — the decode_32k traffic shape); tokens/sec projects the
    roofline-dominant step time onto the occupied slots. Both land in
    the dry-run record so slot-count / mesh choices are comparable
    across cells before any hardware run.

    The ``paged`` sub-record replays the same trace through a
    ``serve.paging.PagePool`` sized to the full contiguous footprint:
    ``peak_pages`` vs ``n_pages`` is the fraction of the contiguous
    cache a right-sized pool would actually need, and
    ``internal_fragmentation`` is the token capacity wasted inside
    allocated pages (the partial-last-page cost the page size trades
    against table size).

    The ``router`` sub-record is the trace-driven multi-replica dryrun
    (``serve.router.simulate_replicas``): a Poisson arrival trace with
    per-request deadlines is routed over 2 replicas of this cell under
    each routing policy, using the cell's roofline step time as the
    per-step cost model — p50/p99 TTFT/latency and SLO attainment per
    policy, comparable across cells before any hardware run. (Slot
    count is capped at 16 for the routing replay: the policy
    comparison, not the absolute slot count, is the signal — the
    uncapped admission replay above keeps the cell's real slots.)
    """
    from repro.serve.paging import PagePool, pages_for
    from repro.serve.router import (
        POLICIES, make_arrival_trace, simulate_replicas,
    )
    from repro.serve.scheduler import Request, simulate_admission

    slots = shp.global_batch
    rng = np.random.default_rng(slots * 7 + shp.seq_len)
    reqs = [
        Request(rid=i, tokens=np.zeros(1, np.int32),
                max_new_tokens=int(rng.integers(32, 129)),
                arrival=(i // max(slots, 1)) * 48)
        for i in range(slots * 3)
    ]
    sim = simulate_admission(slots, reqs)
    step_s = max(rl.t_compute, rl.t_memory, rl.t_collective)
    tps = (slots * sim["occupancy"] / step_s) if step_s > 0 else 0.0

    cache_len = max(r.prompt_len + r.max_new_tokens for r in reqs)
    max_pages = pages_for(cache_len, page_size)
    pool = PagePool(page_size, slots * max_pages, slots, max_pages)
    paged_sim = simulate_admission(
        slots, [Request(rid=r.rid, tokens=r.tokens,
                        max_new_tokens=r.max_new_tokens,
                        arrival=r.arrival) for r in reqs], pool=pool)
    paging = paged_sim.pop("paging")
    peak_tokens = paging["peak_pages"] * page_size

    step_us = step_s * 1e6 if step_s > 0 else 1.0
    rslots = min(slots, 16)
    rtrace = make_arrival_trace(
        np.random.default_rng(slots * 13 + shp.seq_len), rslots * 6,
        mean_gap_steps=0.5, deadline_slack=4.0, step_time_us=step_us)
    router: dict = {"replicas": 2, "slots_per_replica": rslots,
                    "step_time_us": round(step_us, 3), "policies": {}}
    for pol in POLICIES:
        rsim = simulate_replicas(rtrace, 2, policy=pol, n_slots=rslots,
                                 step_time_us=step_us)
        router["policies"][pol] = {
            "ttft_us": rsim["ttft_us"],
            "latency_us": rsim["latency_us"],
            "slo_attainment": rsim["slo_attainment"],
        }
    return {
        **sim,
        "chips": chips,
        "roofline_step_us": round(step_s * 1e6, 3),
        "tokens_per_sec_estimate": round(tps, 1),
        "paged": {
            **paging,
            "contiguous_tokens": slots * cache_len,
            "peak_tokens": peak_tokens,
            # what a right-sized pool pins vs the contiguous cache's
            # slots*cache_len — page-padding overhead included, so the
            # win shrinks as internal fragmentation grows
            "footprint_vs_contiguous": round(
                peak_tokens / (slots * cache_len), 4),
            "page_stalls": paged_sim.get("page_stalls", 0),
        },
        "router": router,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             extra_over=None, policy=None, save: bool = True,
             tag: str = "") -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    shp = SHAPES[shape_name]
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "kind": shp.kind}
    if not cell_is_runnable(arch, shape_name):
        rec["status"] = "skipped"
        rec["reason"] = ("long_500k needs sub-quadratic attention; "
                         "this arch is pure full-attention (DESIGN.md §4)")
        if save:
            _save(rec, tag)
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    step_fn, args, in_sh, donate, rules, cfg = build_cell(
        arch, shape_name, mesh, extra_over, policy)
    try:
        with use_rules(rules):
            jitted = jax.jit(step_fn, in_shardings=in_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        # jax API drift: older versions return [per-computation dict]
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        # loop-aware analysis (XLA's cost_analysis counts scan bodies once)
        from repro.launch.hlo_cost import analyze as hlo_analyze
        from repro.launch.hlo_cost import f32_convert_overhead
        lc = hlo_analyze(hlo)
        cvt = f32_convert_overhead(hlo)
        flops = float(lc.flops)
        bts = float(lc.bytes)
        mf = model_flops(cfg, shp, chips)
        rl = roofline(flops, bts, lc.collective_total, mf)
        rec.update({
            "status": "ok",
            "chips": chips,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "alias_bytes": int(mem.alias_size_in_bytes),
                "peak_bytes_per_device": int(
                    mem.argument_size_in_bytes + mem.output_size_in_bytes
                    + mem.temp_size_in_bytes - mem.alias_size_in_bytes),
                # XLA:CPU lowers bf16 dots via hoisted f32 converts that a
                # TPU build does not allocate; subtracting their (upper
                # bound) size gives the TPU-adjusted estimate.
                "cpu_f32_convert_bytes": int(cvt),
                "peak_bytes_tpu_estimate": int(max(
                    mem.argument_size_in_bytes + mem.output_size_in_bytes
                    + mem.temp_size_in_bytes - mem.alias_size_in_bytes
                    - cvt,
                    mem.argument_size_in_bytes)),
            },
            "cost": {"flops_per_device": flops,
                     "bytes_per_device": bts,
                     "xla_flops_unrolled_once": float(
                         cost.get("flops", 0.0)),
                     "xla_bytes_unrolled_once": float(
                         cost.get("bytes accessed", 0.0))},
            "collectives": {
                "bytes_by_op": lc.coll_bytes,
                "count_by_op": lc.coll_count,
                "total_bytes": lc.collective_total,
            },
            "roofline": rl.as_dict(),
            "csb_partition": csb_partition_report(cfg, mesh),
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
        })
        if shp.kind == "decode":
            rec["serve"] = serve_report(cfg, shp, rl, chips)
        if shp.kind == "train":
            # grad all-reduce traffic with/without the int8
            # error-feedback compressor (TrainConfig.compress_grads):
            # int8 codes + one fp32 scale per leaf on the wire
            leaves = jax.tree.leaves(abstract_params(cfg))
            fp32 = sum(int(np.prod(l.shape)) * 4 for l in leaves)
            int8 = sum(int(np.prod(l.shape)) + 4 for l in leaves)
            rec["collectives"]["grad_compress"] = {
                "allreduce_bytes_fp32": fp32,
                "allreduce_bytes_int8_ef": int8,
                "ratio": round(fp32 / max(int8, 1), 3),
                "enabled_by": "TrainConfig.compress_grads",
            }
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    if save:
        _save(rec, tag)
    return rec


def _save(rec: dict, tag: str = "") -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(
        RESULTS_DIR,
        f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for a, s in all_cells():
            run = "RUN " if cell_is_runnable(a, s) else "SKIP"
            print(f"{run} {a:24s} {s}")
        return 0

    if args.sweep:
        ok = err = skip = 0
        for a, s in all_cells():
            for mp in ([False, True] if args.both_meshes
                       else [args.multi_pod]):
                rec = run_cell(a, s, mp)
                st = rec["status"]
                ok += st == "ok"
                err += st == "error"
                skip += st == "skipped"
                extra = ""
                if st == "ok":
                    extra = (f"compile {rec['compile_s']}s "
                             f"dom={rec['roofline']['dominant']}")
                elif st == "error":
                    extra = rec["error"][:120]
                print(f"[{st:7s}] {a} {s} "
                      f"{'multi' if mp else 'single'} {extra}",
                      flush=True)
        print(f"sweep done: {ok} ok, {skip} skipped, {err} errors")
        return 1 if err else 0

    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --sweep/--list)")
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    code = 0
    for mp in meshes:
        rec = run_cell(args.arch, args.shape, mp)
        print(json.dumps(
            {k: v for k, v in rec.items() if k != "traceback"}, indent=1))
        if rec["status"] == "error":
            print(rec.get("traceback", ""), file=sys.stderr)
            code = 1
    return code


if __name__ == "__main__":
    sys.exit(main())
