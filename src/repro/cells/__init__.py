"""repro.cells — the paper's RNN cell zoo as programmable dataflow graphs."""
from .dataflow import (
    CellGraph,
    GraphBuilder,
    Op,
    cell_apply,
    init_params,
    init_state,
    rnn_scan,
)
from .cells import CELL_BUILDERS, gru, ligru, lstm, lstmp, make_cell

__all__ = [
    "CellGraph", "GraphBuilder", "Op", "cell_apply", "init_params",
    "init_state", "rnn_scan",
    "CELL_BUILDERS", "lstm", "gru", "lstmp", "ligru", "make_cell",
]
