"""The four RNN cell types the paper evaluates (Table 1): LSTM, GRU,
LSTMP (LSTM w/ recurrent projection, Sak et al.) and Li-GRU (Ravanelli
et al.), each expressed as a dataflow graph over the paper's primitives.
"""
from __future__ import annotations

from .dataflow import CellGraph, GraphBuilder


def lstm(input_dim: int, hidden_dim: int) -> CellGraph:
    g = GraphBuilder("lstm", input_dim, hidden_dim)
    x, h, c = g.input("x"), g.input("h"), g.input("c")
    i = g.gate("i", x, h, "sigmoid", input_dim, hidden_dim)
    f = g.gate("f", x, h, "sigmoid", input_dim, hidden_dim)
    o = g.gate("o", x, h, "sigmoid", input_dim, hidden_dim)
    gg = g.gate("g", x, h, "tanh", input_dim, hidden_dim)
    c_new = g.add(g.mul(f, c), g.mul(i, gg))
    h_new = g.mul(o, g.tanh(c_new))
    return g.build(("h", "c"), {"h": h_new, "c": c_new}, h_new)


def gru(input_dim: int, hidden_dim: int) -> CellGraph:
    g = GraphBuilder("gru", input_dim, hidden_dim)
    x, h = g.input("x"), g.input("h")
    z = g.gate("z", x, h, "sigmoid", input_dim, hidden_dim)
    r = g.gate("r", x, h, "sigmoid", input_dim, hidden_dim)
    rh = g.mul(r, h)
    wx = g.mvm("W_n", x, hidden_dim, input_dim)
    un = g.mvm("U_n", rh, hidden_dim, hidden_dim)
    n = g.tanh(g.bias("b_n", g.add(wx, un), hidden_dim))
    h_new = g.add(g.mul(z, h), g.mul(g.one_minus(z), n))
    return g.build(("h",), {"h": h_new}, h_new)


def lstmp(input_dim: int, hidden_dim: int, proj_dim: int) -> CellGraph:
    """LSTM with a recurrent projection layer (paper benchmark SR1)."""
    g = GraphBuilder("lstmp", input_dim, hidden_dim)
    x, h, c = g.input("x"), g.input("h"), g.input("c")  # h: (proj_dim,)
    i = g.gate("i", x, h, "sigmoid", input_dim, proj_dim, hidden_dim)
    f = g.gate("f", x, h, "sigmoid", input_dim, proj_dim, hidden_dim)
    o = g.gate("o", x, h, "sigmoid", input_dim, proj_dim, hidden_dim)
    gg = g.gate("g", x, h, "tanh", input_dim, proj_dim, hidden_dim)
    c_new = g.add(g.mul(f, c), g.mul(i, gg))
    m = g.mul(o, g.tanh(c_new))
    h_new = g.mvm("W_proj", m, proj_dim, hidden_dim)
    return g.build(("h", "c"), {"h": h_new, "c": c_new}, h_new)


def ligru(input_dim: int, hidden_dim: int) -> CellGraph:
    """Light GRU: no reset gate, ReLU candidate (batch-norm folded)."""
    g = GraphBuilder("ligru", input_dim, hidden_dim)
    x, h = g.input("x"), g.input("h")
    z = g.gate("z", x, h, "sigmoid", input_dim, hidden_dim)
    wx = g.mvm("W_n", x, hidden_dim, input_dim)
    un = g.mvm("U_n", h, hidden_dim, hidden_dim)
    n = g.relu(g.bias("b_n", g.add(wx, un), hidden_dim))
    h_new = g.add(g.mul(z, h), g.mul(g.one_minus(z), n))
    return g.build(("h",), {"h": h_new}, h_new)


CELL_BUILDERS = {
    "lstm": lstm,
    "gru": gru,
    "lstmp": lstmp,
    "ligru": ligru,
}


def make_cell(kind: str, input_dim: int, hidden_dim: int,
              proj_dim: int | None = None) -> CellGraph:
    if kind == "lstmp":
        return lstmp(input_dim, hidden_dim, proj_dim or hidden_dim // 2)
    return CELL_BUILDERS[kind](input_dim, hidden_dim)
