"""Programmable RNN dataflow (paper §4.2 / §5.1).

An RNN cell is a DAG of the paper's arithmetic primitives — MVM
(CSB-Engine), element-wise mul/add, sigmoid, tanh (+ relu and 1-x, needed
by Li-GRU/GRU). The same graph object serves three consumers:

1. the **executor** (`cell_apply`) — a small interpreter that traces the
   DAG into a jaxpr, so every cell type runs on one code path (the paper's
   "programmable datapath"). MVM weights may be dense arrays, `PaddedCSB`
   matrices (Pallas CSB kernel), or device-stacked `ShardedCSB` shards
   (mesh-sharded kernel; requires an active `use_rules` mesh with a
   non-trivial "model" axis — see `dist.csb_partition`);
2. the **macro-instruction compiler** (`engine/isa.py`) — list-schedules
   the DAG into VLIW words, reproducing §5.1.2;
3. the **latency model** (`engine/simulator.py`).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.csb_format import PaddedCSB, ShardedCSB

KINDS = ("input", "mvm", "bias", "add", "mul",
         "sigmoid", "tanh", "relu", "one_minus")


@dataclasses.dataclass(frozen=True)
class Op:
    name: str
    kind: str
    inputs: tuple[str, ...] = ()
    shape: tuple[int, int] | None = None  # (out, in) for mvm; (out,) bias

    def __post_init__(self):
        assert self.kind in KINDS, self.kind


@dataclasses.dataclass(frozen=True)
class CellGraph:
    """A cell = DAG + state protocol."""

    name: str
    input_dim: int
    hidden_dim: int
    ops: tuple[Op, ...]
    state_vars: tuple[str, ...]          # e.g. ("h", "c") — fed as inputs
    next_state: dict[str, str]           # state var -> producing op name
    output: str                          # op name of the cell output h_t

    def op(self, name: str) -> Op:
        for o in self.ops:
            if o.name == name:
                return o
        raise KeyError(name)

    @property
    def mvm_ops(self) -> tuple[Op, ...]:
        return tuple(o for o in self.ops if o.kind == "mvm")

    def weight_shapes(self) -> dict[str, tuple[int, ...]]:
        out = {}
        for o in self.ops:
            if o.kind in ("mvm", "bias"):
                out[o.name] = o.shape
        return out

    def param_count(self) -> int:
        return int(sum(np.prod(s) for s in self.weight_shapes().values()))


class GraphBuilder:
    """Tiny DSL for cell graphs."""

    def __init__(self, name: str, input_dim: int, hidden_dim: int):
        self.name = name
        self.input_dim = input_dim
        self.hidden_dim = hidden_dim
        self._ops: list[Op] = []
        self._n = 0

    def _emit(self, kind, inputs=(), shape=None, name=None) -> str:
        name = name or f"{kind}{self._n}"
        self._n += 1
        self._ops.append(Op(name, kind, tuple(inputs), shape))
        return name

    def input(self, name: str) -> str:
        return self._emit("input", name=name)

    def mvm(self, w_name: str, x: str, out_dim: int, in_dim: int) -> str:
        return self._emit("mvm", (x,), (out_dim, in_dim), name=w_name)

    def bias(self, b_name: str, x: str, dim: int) -> str:
        return self._emit("bias", (x,), (dim,), name=b_name)

    def add(self, a: str, b: str) -> str:
        return self._emit("add", (a, b))

    def mul(self, a: str, b: str) -> str:
        return self._emit("mul", (a, b))

    def sigmoid(self, a: str) -> str:
        return self._emit("sigmoid", (a,))

    def tanh(self, a: str) -> str:
        return self._emit("tanh", (a,))

    def relu(self, a: str) -> str:
        return self._emit("relu", (a,))

    def one_minus(self, a: str) -> str:
        return self._emit("one_minus", (a,))

    def gate(self, prefix: str, x: str, h: str, act: str,
             in_dim: int, hid: int, out_dim: int | None = None) -> str:
        """act(W@x + U@h + b) — the standard RNN gate idiom."""
        out_dim = out_dim or hid
        wx = self.mvm(f"W_{prefix}", x, out_dim, in_dim)
        uh = self.mvm(f"U_{prefix}", h, out_dim, hid)
        s = self.add(wx, uh)
        s = self.bias(f"b_{prefix}", s, out_dim)
        return getattr(self, act)(s)

    def build(self, state_vars, next_state, output) -> CellGraph:
        return CellGraph(
            name=self.name, input_dim=self.input_dim,
            hidden_dim=self.hidden_dim, ops=tuple(self._ops),
            state_vars=tuple(state_vars), next_state=dict(next_state),
            output=output,
        )


# ---------------------------------------------------------------------------
# Executor
# ---------------------------------------------------------------------------

def _apply_mvm(w, x: jax.Array) -> jax.Array:
    if isinstance(w, ShardedCSB):
        from repro.core.csb_linear import _active_model_mesh
        from repro.kernels.csb_sharded import csb_matvec_sharded
        mesh = _active_model_mesh()
        if mesh is None:
            raise ValueError(
                "ShardedCSB cell weight needs an active use_rules scope "
                "whose mesh has a non-trivial 'model' axis")
        return csb_matvec_sharded(w, x, mesh=mesh).astype(x.dtype)
    if isinstance(w, PaddedCSB):
        from repro.kernels.ops import csb_matvec
        return csb_matvec(w, x).astype(x.dtype)
    return jnp.einsum("...i,oi->...o", x, w.astype(x.dtype))


def cell_apply(
    graph: CellGraph,
    params: dict[str, jax.Array | PaddedCSB],
    x: jax.Array,
    state: dict[str, jax.Array],
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """One cell step. x: (..., input_dim); state vars: (..., hidden_dim)."""
    env: dict[str, jax.Array] = {"x": x, **state}
    for op in graph.ops:
        if op.kind == "input":
            assert op.name in env, f"missing input {op.name}"
            continue
        a = env[op.inputs[0]]
        if op.kind == "mvm":
            env[op.name] = _apply_mvm(params[op.name], a)
        elif op.kind == "bias":
            env[op.name] = a + params[op.name].astype(a.dtype)
        elif op.kind == "add":
            env[op.name] = a + env[op.inputs[1]]
        elif op.kind == "mul":
            env[op.name] = a * env[op.inputs[1]]
        elif op.kind == "sigmoid":
            env[op.name] = jax.nn.sigmoid(a)
        elif op.kind == "tanh":
            env[op.name] = jnp.tanh(a)
        elif op.kind == "relu":
            env[op.name] = jax.nn.relu(a)
        elif op.kind == "one_minus":
            env[op.name] = 1.0 - a
        else:  # pragma: no cover
            raise ValueError(op.kind)
    new_state = {k: env[v] for k, v in graph.next_state.items()}
    return env[graph.output], new_state


def init_state(graph: CellGraph, batch_shape: tuple[int, ...],
               dtype=jnp.float32) -> dict[str, jax.Array]:
    dims = {"h": graph.hidden_dim, "c": graph.hidden_dim}
    # LSTMP: h is the projected (output) dim
    out_op = graph.op(graph.next_state.get("h", graph.output))
    if out_op.kind == "mvm" and out_op.shape is not None:
        dims["h"] = out_op.shape[0]
    return {
        k: jnp.zeros((*batch_shape, dims.get(k, graph.hidden_dim)), dtype)
        for k in graph.state_vars
    }


def init_params(graph: CellGraph, key: jax.Array,
                dtype=jnp.float32, scale: float | None = None) -> dict:
    params = {}
    for name, shape in graph.weight_shapes().items():
        key, sub = jax.random.split(key)
        if len(shape) == 1:
            params[name] = jnp.zeros(shape, dtype)
        else:
            s = scale or (1.0 / np.sqrt(shape[1]))
            params[name] = (jax.random.normal(sub, shape) * s).astype(dtype)
    return params


def rnn_scan(
    graph: CellGraph,
    params: dict,
    xs: jax.Array,                      # (T, ..., input_dim)
    state: dict[str, jax.Array] | None = None,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Run the cell over a sequence with lax.scan (time-major)."""
    if state is None:
        state = init_state(graph, xs.shape[1:-1], xs.dtype)

    def step(carry, x_t):
        y, new = cell_apply(graph, params, x_t, carry)
        return new, y

    final, ys = jax.lax.scan(step, state, xs)
    return ys, final
