"""Synthetic task generators (numpy, deterministic per (seed, step)).

``CharLMTask``    — order-2 Markov chain text: a learnable LM task whose
                    optimal perplexity is known to be far below uniform,
                    so "loss goes down" is a meaningful signal.
``CopyTask``      — emit the input sequence after a delay (classic RNN
                    memory benchmark; used for lossless-pruning evals).
``AddingTask``    — sum two marked positions (regression; stock-price
                    stand-in for the paper's SPP benchmark).
``SeqClassifyTask`` — class = argmax of class-conditioned pattern score
                    (sentiment/QA stand-in).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CharLMTask:
    vocab: int = 64
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # sparse-ish row-stochastic transition table over (prev2, prev1)
        raw = rng.gamma(0.3, size=(self.vocab, self.vocab, self.vocab))
        self.trans = raw / raw.sum(-1, keepdims=True)

    def batch(self, step: int, batch: int, seq: int):
        rng = np.random.default_rng((self.seed, step))
        toks = np.zeros((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, batch)
        toks[:, 1] = rng.integers(0, self.vocab, batch)
        u = rng.random((batch, seq + 1))
        for t in range(2, seq + 1):
            p = self.trans[toks[:, t - 2], toks[:, t - 1]]
            cdf = np.cumsum(p, -1)
            toks[:, t] = (u[:, t, None] > cdf).sum(-1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclasses.dataclass
class CopyTask:
    vocab: int = 8          # symbols 1..vocab-1; 0 = blank
    copy_len: int = 8
    delay: int = 16
    seed: int = 0

    @property
    def seq_len(self) -> int:
        return self.copy_len + self.delay + self.copy_len

    def batch(self, step: int, batch: int):
        rng = np.random.default_rng((self.seed, step))
        pat = rng.integers(1, self.vocab, (batch, self.copy_len))
        seq = np.zeros((batch, self.seq_len), np.int32)
        seq[:, : self.copy_len] = pat
        labels = np.full((batch, self.seq_len), -1, np.int32)
        labels[:, -self.copy_len:] = pat
        return {"tokens": seq, "labels": labels}


@dataclasses.dataclass
class AddingTask:
    seq_len: int = 64
    seed: int = 0

    def batch(self, step: int, batch: int):
        rng = np.random.default_rng((self.seed, step))
        vals = rng.random((batch, self.seq_len)).astype(np.float32)
        marks = np.zeros((batch, self.seq_len), np.float32)
        idx = np.stack([rng.choice(self.seq_len, 2, replace=False)
                        for _ in range(batch)])
        rows = np.arange(batch)
        marks[rows, idx[:, 0]] = 1.0
        marks[rows, idx[:, 1]] = 1.0
        target = vals[rows, idx[:, 0]] + vals[rows, idx[:, 1]]
        x = np.stack([vals, marks], -1)           # (B, S, 2)
        return {"inputs": x, "targets": target.astype(np.float32)}


@dataclasses.dataclass
class SeqClassifyTask:
    vocab: int = 32
    n_classes: int = 4
    seq_len: int = 48
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.class_logits = rng.normal(size=(self.n_classes, self.vocab))

    def batch(self, step: int, batch: int):
        rng = np.random.default_rng((self.seed, step))
        cls = rng.integers(0, self.n_classes, batch)
        p = np.exp(self.class_logits[cls] * 0.8)
        p = p / p.sum(-1, keepdims=True)
        toks = np.stack([rng.choice(self.vocab, self.seq_len, p=pi)
                         for pi in p]).astype(np.int32)
        return {"tokens": toks, "labels": cls.astype(np.int32)}


def lm_batch_iterator(task: CharLMTask, batch: int, seq: int,
                      start_step: int = 0):
    step = start_step
    while True:
        yield step, task.batch(step, batch, seq)
        step += 1
