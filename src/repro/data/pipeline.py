"""Pull-based prefetching pipeline.

``Prefetcher`` runs the generator in a daemon thread with a bounded
queue — the classic straggler absorber: a slow host-side batch
generation step doesn't stall the accelerator as long as the queue has
depth. ``sharded_batches`` device_puts each numpy batch with the dp
sharding so jit consumes committed global arrays (no implicit transfer
inside the step).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Iterator

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


class Prefetcher:
    _SENTINEL = object()

    def __init__(self, it: Iterator[Any], depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._err: BaseException | None = None

        def run():
            try:
                for item in it:
                    self._q.put(item)
            except BaseException as e:  # surface in consumer
                self._err = e
            finally:
                self._q.put(self._SENTINEL)

        self._t = threading.Thread(target=run, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._SENTINEL:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


def sharded_batches(it: Iterator[dict], mesh: Mesh | None,
                    specs: dict[str, PartitionSpec] | None,
                    prefetch: int = 2):
    """Wrap a (step, batch) iterator: device_put with dp sharding."""

    def put(batch: dict) -> dict:
        if mesh is None or specs is None:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        out = {}
        for k, v in batch.items():
            spec = specs.get(k, PartitionSpec())
            out[k] = jax.device_put(v, NamedSharding(mesh, spec))
        return out

    def gen():
        for step, batch in it:
            yield step, put(batch)

    return Prefetcher(gen(), depth=prefetch)
