"""repro.data — deterministic synthetic data pipeline.

The container is offline: PTB/TIMIT/… are not redistributable here, so
the paper's tasks are stood in for by synthetic generators with the same
tensor interfaces (sequence classification / char-LM / regression). The
pipeline itself is production-shaped: deterministic per-(seed, step)
batches (restart-safe — a resumed job regenerates the identical stream),
a background prefetcher (straggler absorption), and per-dp-shard slicing.
"""
from .synthetic import (
    AddingTask,
    CharLMTask,
    CopyTask,
    SeqClassifyTask,
    lm_batch_iterator,
)
from .pipeline import Prefetcher, sharded_batches

__all__ = [
    "CharLMTask", "CopyTask", "AddingTask", "SeqClassifyTask",
    "lm_batch_iterator", "Prefetcher", "sharded_batches",
]
