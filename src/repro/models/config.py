"""Unified model configuration covering all 10 assigned architectures.

One decoder skeleton (embed -> scanned layers -> norm -> head) with a
per-family *mixer* (attention / MLA / SSD / hybrid) and *ffn*
(dense / GeGLU / MoE). Uniform layers keep the stack scannable so compile
time is O(1) in depth.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"        # dense | moe | ssm | hybrid | audio | vlm
    mixer: str = "attn"          # attn | mla | ssd | hybrid
    ffn: str = "swiglu"          # swiglu | geglu | moe | none

    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv: int = 4
    head_dim: int | None = None  # default d_model // n_heads
    d_ff: int = 1024
    vocab: int = 1000

    # attention details
    qk_norm: bool = False
    rope_theta: float = 10000.0
    window: int | None = None    # sliding-window size for long-context

    # MLA (deepseek)
    kv_lora: int = 0
    q_lora: int = 0
    rope_head_dim: int = 64

    # MoE
    n_experts: int = 0
    top_k: int = 1
    n_shared: int = 0
    moe_dff: int = 0             # per-expert hidden (deepseek: 1536)
    capacity_factor: float = 1.25
    moe_chunk: int = 4096        # tokens per dispatch chunk (memory knob)

    # SSM (mamba2 SSD)
    d_state: int = 0
    ssd_expand: int = 2
    ssd_headdim: int = 64
    ssd_chunk: int = 256
    conv_k: int = 4
    # split the fused in-projection into (z, x, BC, dt) weights so each
    # is individually model-shardable — needed when the fused output dim
    # (2*d_inner + 2*d_state + heads) does not divide the model axis
    # (hymba: 3257). §Perf iter log.
    ssd_split_proj: bool = False
    # decode-time SSM state dtype: the state is read+written once per
    # token and dominates SSD decode HBM traffic; bf16 halves it at a
    # small accumulation-precision cost (updates still compute in f32).
    ssd_state_dtype: str = "float32"

    # hybrid (hymba): fraction of heads that are SSM replaced handled by
    # running both paths on the full width and averaging (see layers.py)

    # modality frontends (stubs per assignment)
    n_codebooks: int = 0         # musicgen
    n_img_tokens: int = 0        # internvl2 (precomputed patch embeds)

    # numerics / training
    dtype: str = "bfloat16"
    remat: bool = True
    logit_chunk: int = 512       # CE loss sequence chunking
    tie_embeddings: bool = False
    # physical embedding-table padding: odd vocabs (50280, 32001, 92553)
    # cannot shard over a 16-way model axis and replicate ~200 MB of
    # embed+head per device; padding to a multiple restores sharding.
    # Logical vocab is unchanged (padded logits are masked). §Perf.
    vocab_pad: int = 1

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab // self.vocab_pad) * self.vocab_pad

    # ---------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssd_expand * self.d_model

    @property
    def ssd_heads(self) -> int:
        return self.d_inner // self.ssd_headdim

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline math)."""
        d, ff, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd, nh, nkv = self.hd, self.n_heads, self.n_kv
        per_layer = 0
        if self.mixer == "attn":
            per_layer += d * (nh * hd) + 2 * d * (nkv * hd) + (nh * hd) * d
        elif self.mixer == "mla":
            qd = nh * (hd + self.rope_head_dim)
            per_layer += (d * self.q_lora + self.q_lora * qd
                          + d * (self.kv_lora + self.rope_head_dim)
                          + self.kv_lora * nh * (hd + hd)
                          + nh * hd * d)
        elif self.mixer == "ssd":
            di = self.d_inner
            per_layer += d * (2 * di + 2 * self.d_state + self.ssd_heads)
            per_layer += di * d + self.conv_k * (di + 2 * self.d_state)
        elif self.mixer == "hybrid":
            per_layer += d * (nh * hd) + 2 * d * (nkv * hd) + (nh * hd) * d
            di = self.d_inner
            per_layer += d * (2 * di + 2 * self.d_state + self.ssd_heads)
            per_layer += di * d + self.conv_k * (di + 2 * self.d_state)
        if self.ffn in ("swiglu", "geglu"):
            per_layer += 3 * d * ff
        elif self.ffn == "moe":
            per_layer += d * self.n_experts  # router
            per_layer += self.n_experts * 3 * d * self.moe_dff
            per_layer += self.n_shared * 3 * d * self.moe_dff
        per_layer += 2 * d  # norms
        emb = v * d * (1 if self.tie_embeddings else 2)
        return L * per_layer + emb + d

    def active_param_count(self) -> int:
        """Activated params per token (MoE counts top_k+shared experts)."""
        if self.ffn != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        full = self.param_count()
        all_experts = L * self.n_experts * 3 * d * self.moe_dff
        active = L * (self.top_k + self.n_shared) * 3 * d * self.moe_dff
        # n_shared already counted once in param_count
        shared = L * self.n_shared * 3 * d * self.moe_dff
        return full - all_experts - shared + active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# Archs whose mixer is sub-quadratic (SSD or hybrid-with-window): the only
# ones for which long_500k is runnable (see DESIGN.md §4).
SUBQUADRATIC = ("ssd", "hybrid")


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    small = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=max(1, min(cfg.n_kv, 2)),
        head_dim=16,
        d_ff=128,
        vocab=128,
        q_lora=32 if cfg.q_lora else 0,
        kv_lora=32 if cfg.kv_lora else 0,
        rope_head_dim=8 if cfg.mixer == "mla" else cfg.rope_head_dim,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2),
        n_shared=min(cfg.n_shared, 1),
        moe_dff=64 if cfg.moe_dff else 0,
        moe_chunk=64,
        d_state=16 if cfg.d_state else 0,
        ssd_headdim=16 if cfg.d_state else 64,
        ssd_chunk=16,
        n_codebooks=cfg.n_codebooks,
        n_img_tokens=min(cfg.n_img_tokens, 8) if cfg.n_img_tokens else 0,
        logit_chunk=64,
        dtype="float32",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
