"""repro.models — unified decoder substrate for the assigned archs."""
from .config import ModelConfig, ShapeConfig, SHAPES, SUBQUADRATIC, reduced
from .lm import (
    abstract_cache,
    abstract_paged_cache,
    abstract_params,
    decode_step,
    decode_step_paged,
    forward_loss,
    init_cache,
    init_paged_cache,
    init_params,
    prefill,
    prefill_partial,
)

__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "SUBQUADRATIC", "reduced",
    "abstract_cache", "abstract_paged_cache", "abstract_params",
    "decode_step", "decode_step_paged", "forward_loss",
    "init_cache", "init_paged_cache", "init_params", "prefill",
    "prefill_partial",
]
