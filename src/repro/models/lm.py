"""Unified decoder LM: embed -> lax.scan(layers) -> norm -> head.

One skeleton serves all ten assigned architectures (dense / MoE / MLA /
SSD / hybrid / audio / vlm). Layers are stacked along a leading L axis and
scanned, so HLO size and compile time are O(1) in depth. ``jax.checkpoint``
on the layer body gives the save-residual-only remat policy.

Modality frontends are stubs per the assignment: musicgen consumes
EnCodec *token* ids over K codebooks (sum of codebook embeddings);
internvl2 consumes precomputed ViT patch embeddings plus text tokens.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.api import replicated, shard
from .config import ModelConfig
from . import layers as L

PyTree = Any
F32 = jnp.float32


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig) -> PyTree:
    dt = jnp.dtype(cfg.dtype)
    k1, k2 = jax.random.split(key)
    mixer_init = {
        "attn": L.attn_init, "mla": L.mla_init,
        "ssd": L.ssd_init, "hybrid": L.hybrid_init,
    }[cfg.mixer]
    p = {"norm1": L.rmsnorm_init(cfg.d_model, dt),
         "mixer": mixer_init(k1, cfg)}
    if cfg.ffn != "none":
        p["norm2"] = L.rmsnorm_init(cfg.d_model, dt)
        p["ffn"] = (L.moe_init(k2, cfg) if cfg.ffn == "moe"
                    else L.mlp_init(k2, cfg))
    return p


def init_params(key, cfg: ModelConfig) -> PyTree:
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, cfg.n_layers + 4)
    lkeys = jax.random.split(keys[0], cfg.n_layers)
    params: dict[str, Any] = {
        "layers": jax.vmap(lambda k: init_layer(k, cfg))(lkeys),
        "final_norm": L.rmsnorm_init(cfg.d_model, dt),
    }
    if cfg.n_codebooks:  # musicgen: per-codebook embeddings + heads
        params["embed"] = (jax.random.normal(
            keys[1], (cfg.n_codebooks, cfg.vocab, cfg.d_model)) * 0.02
        ).astype(dt)
        params["head"] = L.dense_init(
            keys[2], cfg.d_model, cfg.n_codebooks * cfg.vocab, dt)
    else:
        params["embed"] = (jax.random.normal(
            keys[1], (cfg.padded_vocab, cfg.d_model)) * 0.02).astype(dt)
        if not cfg.tie_embeddings:
            params["head"] = L.dense_init(keys[2], cfg.d_model,
                                          cfg.padded_vocab, dt)
    if cfg.n_img_tokens:  # internvl2: project stub ViT embeddings
        params["img_proj"] = L.dense_init(keys[3], 1024, cfg.d_model, dt)
    return params


def abstract_params(cfg: ModelConfig) -> PyTree:
    """ShapeDtypeStruct tree — no allocation (dry-run path)."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------

def layer_apply(lp, x, cfg: ModelConfig, window=None):
    h = L.rmsnorm(x, lp["norm1"])
    if cfg.mixer == "attn":
        mix = L.attn_apply(lp["mixer"], h, cfg, window=window)
    elif cfg.mixer == "mla":
        mix = L.mla_apply(lp["mixer"], h, cfg, window=window)
    elif cfg.mixer == "ssd":
        mix = L.ssd_block_apply(lp["mixer"], h, cfg)
    elif cfg.mixer == "hybrid":
        mix = L.hybrid_apply(lp["mixer"], h, cfg, window=window)
    else:  # pragma: no cover
        raise ValueError(cfg.mixer)
    x = x + mix
    if cfg.ffn != "none":
        h2 = L.rmsnorm(x, lp["norm2"])
        f = (L.moe_apply(lp["ffn"], h2, cfg) if cfg.ffn == "moe"
             else L.mlp_apply(lp["ffn"], h2, cfg))
        x = x + f
    return shard(x, "residual")


def layer_decode(lp, x, cache_l, pos, cfg: ModelConfig):
    h = L.rmsnorm(x, lp["norm1"])
    if cfg.mixer == "attn":
        mix, nc = L.attn_decode(lp["mixer"], h, cfg, cache_l, pos)
    elif cfg.mixer == "mla":
        mix, nc = L.mla_decode(lp["mixer"], h, cfg, cache_l, pos)
    elif cfg.mixer == "ssd":
        mix, conv, ssm = L.ssd_block_apply(
            lp["mixer"], h, cfg, conv_state=cache_l["conv"],
            ssm_state=cache_l["ssm"], decode=True)
        nc = {"conv": conv, "ssm": ssm}
    elif cfg.mixer == "hybrid":
        mix, nc = L.hybrid_decode(lp["mixer"], h, cfg, cache_l, pos)
    else:  # pragma: no cover
        raise ValueError(cfg.mixer)
    x = x + mix
    if cfg.ffn != "none":
        h2 = L.rmsnorm(x, lp["norm2"])
        f = (L.moe_apply(lp["ffn"], h2, cfg) if cfg.ffn == "moe"
             else L.mlp_apply(lp["ffn"], h2, cfg))
        x = x + f
    return x, nc


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def embed_tokens(params, tokens, cfg: ModelConfig):
    if cfg.n_codebooks:
        # tokens: (B, S, K) — sum codebook embeddings
        parts = [params["embed"][k][tokens[..., k]]
                 for k in range(cfg.n_codebooks)]
        x = sum(parts)
    else:
        x = params["embed"][tokens]
    return shard(x.astype(jnp.dtype(cfg.dtype)), "residual")


def backbone(params, x, cfg: ModelConfig, window=None):
    """x: (B, S, d) embeddings -> final hidden states."""
    fn = partial(layer_apply, cfg=cfg, window=window)
    if cfg.remat:
        fn = jax.checkpoint(fn)

    def body(carry, lp):
        return fn(lp, carry), None

    x, _ = jax.lax.scan(body, x, params["layers"])
    return L.rmsnorm(x, params["final_norm"])


def head_weight(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["head"]


def lm_loss(params, hidden, labels, cfg: ModelConfig):
    """Chunked CE over the sequence; labels == -1 are masked.

    hidden: (B, S, d); labels: (B, S) or (B, S, K) for codebooks.
    """
    b, s, d = hidden.shape
    ck = min(cfg.logit_chunk, s)
    sp = -(-s // ck) * ck
    hp = jnp.pad(hidden, ((0, 0), (0, sp - s), (0, 0)))
    lab_pad = [(0, 0), (0, sp - s)] + [(0, 0)] * (labels.ndim - 2)
    lp = jnp.pad(labels, lab_pad, constant_values=-1)
    g = sp // ck
    hs = hp.reshape(b, g, ck, d).transpose(1, 0, 2, 3)
    ls = lp.reshape((b, g, ck) + labels.shape[2:]).swapaxes(0, 1)
    w = head_weight(params, cfg)

    def chunk(acc, inp):
        hc, lc = inp
        logits = jnp.einsum("btd,dv->btv", hc.astype(F32), w.astype(F32))
        if cfg.n_codebooks:
            logits = logits.reshape(b, ck, cfg.n_codebooks, cfg.vocab)
        logits = shard(logits, "logits")
        vocab_iota = jnp.arange(logits.shape[-1])
        if logits.shape[-1] != cfg.vocab:   # mask padded vocab rows
            logits = jnp.where(vocab_iota < cfg.vocab, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot_ll = jnp.sum(
            jnp.where(lc[..., None] == vocab_iota, logits, 0.0), axis=-1)
        valid = lc >= 0
        nll = jnp.where(valid, lse - onehot_ll, 0.0)
        loss_sum, count = acc
        return (loss_sum + nll.sum(), count + valid.sum()), None

    (loss_sum, count), _ = jax.lax.scan(chunk, (0.0, 0), (hs, ls))
    return loss_sum / jnp.maximum(count, 1)


# ---------------------------------------------------------------------------
# model entry points
# ---------------------------------------------------------------------------

def assemble_inputs(params, batch, cfg: ModelConfig):
    """Returns (embeddings, labels) handling modality frontends."""
    tokens = batch["tokens"]
    x = embed_tokens(params, tokens, cfg)
    labels = batch.get("labels")
    if cfg.n_img_tokens:
        img = batch["img_embeds"].astype(x.dtype)         # (B, N, 1024)
        iv = L.dense(img, params["img_proj"])             # (B, N, d)
        x = jnp.concatenate([iv, x], axis=1)
        if labels is not None:
            pad = jnp.full(iv.shape[:2], -1, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
    return x, labels


def forward_loss(params, batch, cfg: ModelConfig, window=None):
    x, labels = assemble_inputs(params, batch, cfg)
    hidden = backbone(params, x, cfg, window=window)
    return lm_loss(params, hidden, labels, cfg)


def prefill(params, batch, cfg: ModelConfig, window=None, last_pos=None):
    """Process a full prompt; returns last-position logits + KV cache.

    ``last_pos`` (traced scalar, optional) selects which position's
    logits to return instead of the final one — the prompt-bucketing
    path right-pads prompts to pow2 shapes (one compiled prefill per
    bucket instead of per length) and reads the logits at the real
    prompt end; causal masking makes the right padding invisible to
    every real position.
    """
    x, _ = assemble_inputs(params, batch, cfg)
    b, s, _ = x.shape
    cache = init_cache(cfg, b, s, jnp.dtype(cfg.dtype))
    fn = partial(_prefill_layer, cfg=cfg, window=window, seqlen=s)
    if cfg.remat:
        fn = jax.checkpoint(fn)

    def body(carry, inp):
        lp, _dummy = inp
        x_new, kv = fn(lp, carry)
        return x_new, kv

    x, cache = jax.lax.scan(body, x, (params["layers"], jnp.arange(cfg.n_layers)))
    hidden = L.rmsnorm(x, params["final_norm"])
    if last_pos is None:
        last = hidden[:, -1]
    else:
        lp = jnp.asarray(last_pos, jnp.int32)
        last = jax.lax.dynamic_slice_in_dim(hidden, lp, 1, axis=1)[:, 0]
    logits = jnp.einsum("bd,dv->bv", last.astype(F32),
                        head_weight(params, cfg).astype(F32))
    if logits.shape[-1] != cfg.vocab and not cfg.n_codebooks:
        logits = jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab,
                           logits, -1e30)
    if cfg.n_codebooks:
        logits = logits.reshape(b, cfg.n_codebooks, cfg.vocab)
    return logits, cache


def _prefill_layer(lp, x, cfg: ModelConfig, window, seqlen):
    """Like layer_apply but also emits this layer's populated cache."""
    h = L.rmsnorm(x, lp["norm1"])
    b = x.shape[0]
    dt = jnp.dtype(cfg.dtype)
    if cfg.mixer in ("attn", "hybrid"):
        ap = lp["mixer"]["attn"] if cfg.mixer == "hybrid" else lp["mixer"]
        pos = jnp.arange(seqlen)
        q, k, v = L.attn_qkv(ap, h, cfg, pos)
        o = L.blockwise_attention(q, k, v, causal=True, window=window)
        mix_attn = L.dense(o.reshape(b, seqlen, -1), ap["wo"])
        kv = {"k": shard(k.astype(dt), "kv_cache"),
              "v": shard(v.astype(dt), "kv_cache")}
        if cfg.mixer == "hybrid":
            ys, conv, ssm = _ssd_prefill(lp["mixer"]["ssd"], h, cfg)
            mix = 0.5 * (L.rmsnorm(mix_attn, lp["mixer"]["attn_norm"])
                         + L.rmsnorm(ys, lp["mixer"]["ssd_norm"]))
            kv = {"attn": kv, "ssd": {"conv": conv, "ssm": ssm}}
        else:
            mix = mix_attn
    elif cfg.mixer == "mla":
        pos = jnp.arange(seqlen)
        q_nope, q_rope, c_kv, k_rope = L._mla_qkv(lp["mixer"], h, cfg, pos)
        nh, hd, rd = cfg.n_heads, cfg.hd, cfg.rope_head_dim
        k_nope = L.dense(c_kv, lp["mixer"]["wk_b"]).reshape(b, seqlen, nh, hd)
        v = L.dense(c_kv, lp["mixer"]["wv_b"]).reshape(b, seqlen, nh, hd)
        # replicated(...): unlike attn_qkv, these nope+rope concats sit
        # AFTER _mla_qkv's layout pins, so GSPMD re-guesses their
        # layout going into the attention scans — the transition class
        # dist.api.shard documents as miscompiling on the CPU SPMD
        # backend (observed: layer-0 k_rope off by O(1) on a 2x4 mesh
        # while the same ops jitted alone are exact)
        qq = replicated(jnp.concatenate([q_nope, q_rope], -1))
        kk = replicated(jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, seqlen, nh, rd))], -1))
        o = L.blockwise_attention(qq, kk, replicated(v), causal=True,
                                  window=window)
        mix = L.dense(o.reshape(b, seqlen, -1), lp["mixer"]["wo"])
        kv = {"c_kv": shard(c_kv.astype(dt), "mla_cache"),
              "k_rope": k_rope[:, :, 0].astype(dt)}
    elif cfg.mixer == "ssd":
        mix, conv, ssm = _ssd_prefill(lp["mixer"], h, cfg)
        kv = {"conv": conv, "ssm": ssm}
    else:  # pragma: no cover
        raise ValueError(cfg.mixer)
    x = x + mix
    if cfg.ffn != "none":
        h2 = L.rmsnorm(x, lp["norm2"])
        f = (L.moe_apply(lp["ffn"], h2, cfg) if cfg.ffn == "moe"
             else L.mlp_apply(lp["ffn"], h2, cfg))
        x = x + f
    return shard(x, "residual"), kv


def prefill_partial(params, batch, ctx, cfg: ModelConfig, window=None,
                    start=0, last_pos=None):
    """Prefill only a prompt SUFFIX against an already-computed prefix.

    The prefix-cache admission path: when a prompt's first ``start``
    tokens match pages already in the pool, the engine gathers those
    pages into ``ctx`` (per-layer time leaves shaped (L, 1, C, ...),
    positions ``>= start`` being pad) and prefills just the suffix —
    zero compute for the matched span. ``batch["tokens"]`` holds the
    suffix, whose absolute positions are ``start + arange(S)``.

    Returns logits at suffix position ``last_pos`` (default: the final
    one) plus the SUFFIX-ONLY cache, (L, 1, S, ...) per time leaf, which
    the engine scatters into the pool at positions ``start..start+S``
    (``serve.scheduler.insert_paged_span``). Attention runs through
    :func:`repro.models.layers.context_attention`, a single-chunk mirror
    of the full-prefill math, so at serve scales the suffix KV and
    logits are bit-identical to a from-scratch prefill of the whole
    prompt. Only position-indexed caches support this (attn / mla);
    SSD/hybrid state absorbs every token, so there is no suffix to skip.
    """
    if cfg.mixer not in ("attn", "mla"):
        raise NotImplementedError(
            "prefix-cache partial prefill needs a position-indexed cache "
            f"(attn/mla), not {cfg.mixer!r}")
    x, _ = assemble_inputs(params, batch, cfg)
    b, s, _ = x.shape
    start = jnp.asarray(start, jnp.int32)
    fn = partial(_prefill_partial_layer, cfg=cfg, window=window, seqlen=s,
                 start=start)
    if cfg.remat:
        fn = jax.checkpoint(fn)

    def body(carry, inp):
        lp, ctx_l = inp
        x_new, kv = fn(lp, ctx_l, carry)
        return x_new, kv

    x, cache = jax.lax.scan(body, x, (params["layers"], ctx))
    hidden = L.rmsnorm(x, params["final_norm"])
    if last_pos is None:
        last = hidden[:, -1]
    else:
        lp = jnp.asarray(last_pos, jnp.int32)
        last = jax.lax.dynamic_slice_in_dim(hidden, lp, 1, axis=1)[:, 0]
    logits = jnp.einsum("bd,dv->bv", last.astype(F32),
                        head_weight(params, cfg).astype(F32))
    if logits.shape[-1] != cfg.vocab:
        logits = jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab,
                           logits, -1e30)
    return logits, cache


def _prefill_partial_layer(lp, ctx_l, x, cfg: ModelConfig, window, seqlen,
                           start):
    """``_prefill_layer`` over a suffix: queries at ``start + arange(S)``
    attend the gathered prefix context then themselves; emits the same
    suffix-only kv the full version emits for these positions."""
    h = L.rmsnorm(x, lp["norm1"])
    b = x.shape[0]
    dt = jnp.dtype(cfg.dtype)
    pos = start + jnp.arange(seqlen)
    if cfg.mixer == "attn":
        q, k, v = L.attn_qkv(lp["mixer"], h, cfg, pos)
        o = L.context_attention(q, k, v, ctx_l["k"], ctx_l["v"], start,
                                window=window)
        mix = L.dense(o.reshape(b, seqlen, -1), lp["mixer"]["wo"])
        kv = {"k": shard(k.astype(dt), "kv_cache"),
              "v": shard(v.astype(dt), "kv_cache")}
    elif cfg.mixer == "mla":
        q_nope, q_rope, c_kv, k_rope = L._mla_qkv(lp["mixer"], h, cfg, pos)
        nh, hd, rd = cfg.n_heads, cfg.hd, cfg.rope_head_dim
        k_nope = L.dense(c_kv, lp["mixer"]["wk_b"]).reshape(b, seqlen, nh, hd)
        v = L.dense(c_kv, lp["mixer"]["wv_b"]).reshape(b, seqlen, nh, hd)
        # replicated(...): same pin as _prefill_layer's mla branch — the
        # post-_mla_qkv concats (and here additionally the context
        # up-projections) otherwise hit the layout-transition miscompile
        # dist.api.shard documents, skewing suffix KV/logits off the
        # full-prefill reference on 2x4 meshes. The context is tiny.
        qq = replicated(jnp.concatenate([q_nope, q_rope], -1))
        kk = replicated(jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, seqlen, nh, rd))], -1))
        cc = replicated(ctx_l["c_kv"])
        c = cc.shape[1]
        ctx_k_nope = L.dense(cc, lp["mixer"]["wk_b"]).reshape(b, c, nh, hd)
        ctx_v = replicated(
            L.dense(cc, lp["mixer"]["wv_b"]).reshape(b, c, nh, hd))
        ctx_kk = replicated(jnp.concatenate(
            [ctx_k_nope,
             jnp.broadcast_to(replicated(ctx_l["k_rope"])[:, :, None, :],
                              (b, c, nh, rd))], -1))
        o = L.context_attention(qq, kk, replicated(v), ctx_kk, ctx_v, start,
                                window=window)
        mix = L.dense(o.reshape(b, seqlen, -1), lp["mixer"]["wo"])
        kv = {"c_kv": shard(c_kv.astype(dt), "mla_cache"),
              "k_rope": k_rope[:, :, 0].astype(dt)}
    else:  # pragma: no cover - guarded in prefill_partial
        raise ValueError(cfg.mixer)
    x = x + mix
    if cfg.ffn != "none":
        h2 = L.rmsnorm(x, lp["norm2"])
        f = (L.moe_apply(lp["ffn"], h2, cfg) if cfg.ffn == "moe"
             else L.mlp_apply(lp["ffn"], h2, cfg))
        x = x + f
    return shard(x, "residual"), kv


def _ssd_prefill(p, h, cfg: ModelConfig):
    """SSD forward that also returns final (conv, ssm) states."""
    b, s, _ = h.shape
    di, n = cfg.d_inner, cfg.d_state
    z, conv_in, dtp = L._ssd_in_proj(p, h, cfg)
    # Same layout anchors as layers.ssd_block_apply (see the comment
    # there): without them the in-proj / conv-weight model shardings
    # propagate into the chunked scan and the SPMD partitioner
    # reassociates its reductions — O(1) logit drift on host meshes
    # whenever the batch cannot split over the data axes.
    z = shard(z, "ssd_inner", fallback="replicate")
    conv_in = shard(conv_in, "ssd_inner", fallback="replicate")
    dtp = shard(dtp, "ssd_inner", fallback="replicate")
    cw = L._ssd_conv_weight(p, cfg)
    k = cfg.conv_k
    conv = sum(
        jnp.pad(conv_in, ((0, 0), (k - 1 - i, 0), (0, 0)))[:, : s]
        * cw[i]
        for i in range(k))
    conv_state = conv_in[:, s - (k - 1):, :]
    conv = shard(conv, "ssd_inner", fallback="replicate")
    conv_act = jax.nn.silu(conv)
    xc, bc, cc = jnp.split(conv_act, [di, di + n], axis=-1)
    xh = xc.reshape(b, s, cfg.ssd_heads, cfg.ssd_headdim)
    a = -jnp.exp(p["a_log"])
    dt_full = jax.nn.softplus(dtp.astype(F32) + p["dt_bias"])
    y, final = L.ssd_scan(xh, dt_full, a, bc.astype(F32), cc.astype(F32),
                          cfg.ssd_chunk)
    y = y + xh.astype(F32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, di).astype(h.dtype)
    y = shard(y, "ssd_inner", fallback="replicate")
    y = L.rmsnorm(y, p["out_norm"]) * jax.nn.silu(z)
    return L.dense(y, p["w_out"]), conv_state.astype(h.dtype), final


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    """One token for the whole batch. tokens: (B, 1) or (B, 1, K).

    ``pos`` is a scalar (fixed-batch decode: every row at one depth) or
    a (B,) int vector (continuous batching: each slot at its own depth —
    the serve scheduler refills freed slots mid-decode, so rows diverge).
    """
    x = embed_tokens(params, tokens, cfg)

    def body(carry, inp):
        lp, cl = inp
        # barrier: stops XLA hoisting per-layer cache converts out of the
        # scan as whole-stack buffers (CPU backend lowers bf16 dots via
        # f32 converts; hoisted, they would double cache memory).
        cl = jax.lax.optimization_barrier(cl)
        x_new, nc = layer_decode(lp, carry, cl, pos, cfg)
        return x_new, nc

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    hidden = L.rmsnorm(x, params["final_norm"])
    logits = jnp.einsum("btd,dv->btv", hidden.astype(F32),
                        head_weight(params, cfg).astype(F32))
    if logits.shape[-1] != cfg.vocab and not cfg.n_codebooks:
        logits = jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab,
                           logits, -1e30)
    if cfg.n_codebooks:
        b = logits.shape[0]
        logits = logits.reshape(b, 1, cfg.n_codebooks, cfg.vocab)
    return logits, new_cache


def layer_decode_paged(lp, x, cache_l, pos, page_table, cfg: ModelConfig,
                       use_kernel: bool = False):
    """``layer_decode`` with time-keyed cache leaves routed through the
    paged pool (``serve.paging``); state leaves (SSM/conv) stay
    per-slot. ``use_kernel`` selects the Pallas paged-attention kernel
    over the XLA ``paged_gather`` fallback (tokens match)."""
    h = L.rmsnorm(x, lp["norm1"])
    if cfg.mixer == "attn":
        mix, nc = L.attn_decode_paged(lp["mixer"], h, cfg, cache_l, pos,
                                      page_table, use_kernel)
    elif cfg.mixer == "mla":
        mix, nc = L.mla_decode_paged(lp["mixer"], h, cfg, cache_l, pos,
                                     page_table, use_kernel)
    elif cfg.mixer == "ssd":
        # pure-state cache: nothing to page, identical to layer_decode
        mix, conv, ssm = L.ssd_block_apply(
            lp["mixer"], h, cfg, conv_state=cache_l["conv"],
            ssm_state=cache_l["ssm"], decode=True)
        nc = {"conv": conv, "ssm": ssm}
    elif cfg.mixer == "hybrid":
        mix, nc = L.hybrid_decode_paged(lp["mixer"], h, cfg, cache_l, pos,
                                        page_table, use_kernel)
    else:  # pragma: no cover
        raise ValueError(cfg.mixer)
    x = x + mix
    if cfg.ffn != "none":
        h2 = L.rmsnorm(x, lp["norm2"])
        f = (L.moe_apply(lp["ffn"], h2, cfg) if cfg.ffn == "moe"
             else L.mlp_apply(lp["ffn"], h2, cfg))
        x = x + f
    return x, nc


def decode_step_paged(params, cache, tokens, pos, page_table,
                      cfg: ModelConfig, use_kernel: bool = False):
    """One decode token over the slot batch through the paged cache.

    Same contract as :func:`decode_step` (scalar or (B,) ``pos``), but
    time-keyed cache leaves are page pools shaped (L, N, P, ...) shared
    by all slots, indexed through ``page_table`` (B, max_pages) — the
    dense int32 map ``serve.paging.PagePool.device_table`` maintains.
    The table is identical for every layer, so it rides into the layer
    scan as a closure constant rather than a scanned input.
    ``use_kernel=True`` swaps the per-layer ``paged_gather`` attention
    for the Pallas paged-attention kernel (``kernels.paged_attn``).
    """
    x = embed_tokens(params, tokens, cfg)

    def body(carry, inp):
        lp, cl = inp
        cl = jax.lax.optimization_barrier(cl)   # see decode_step
        x_new, nc = layer_decode_paged(lp, carry, cl, pos, page_table,
                                       cfg, use_kernel)
        return x_new, nc

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    hidden = L.rmsnorm(x, params["final_norm"])
    logits = jnp.einsum("btd,dv->btv", hidden.astype(F32),
                        head_weight(params, cfg).astype(F32))
    if logits.shape[-1] != cfg.vocab and not cfg.n_codebooks:
        logits = jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab,
                           logits, -1e30)
    return logits, new_cache


def init_cache(cfg: ModelConfig, batch: int, t: int, dtype) -> PyTree:
    """Per-layer decode cache stacked on a leading L axis (scannable)."""

    def one(_):
        if cfg.mixer == "attn":
            c = L.attn_cache_init(cfg, batch, t, dtype)
            return {"k": shard(c["k"], "kv_cache"),
                    "v": shard(c["v"], "kv_cache")}
        if cfg.mixer == "mla":
            c = L.mla_cache_init(cfg, batch, t, dtype)
            return {"c_kv": shard(c["c_kv"], "mla_cache"),
                    "k_rope": c["k_rope"]}
        if cfg.mixer == "ssd":
            return L.ssd_cache_init(cfg, batch, dtype)
        if cfg.mixer == "hybrid":
            c = L.attn_cache_init(cfg, batch, t, dtype)
            return {"attn": {"k": shard(c["k"], "kv_cache"),
                             "v": shard(c["v"], "kv_cache")},
                    "ssd": L.ssd_cache_init(cfg, batch, dtype)}
        raise ValueError(cfg.mixer)

    return jax.vmap(one)(jnp.arange(cfg.n_layers))


def abstract_cache(cfg: ModelConfig, batch: int, t: int, dtype) -> PyTree:
    return jax.eval_shape(lambda: init_cache(cfg, batch, t, dtype))


def init_paged_cache(cfg: ModelConfig, n_pages: int, page_size: int,
                     n_slots: int, dtype) -> PyTree:
    """Paged decode cache, stacked on a leading L axis like init_cache.

    Time-keyed leaves become page pools (L, n_pages + 1, page_size, ...)
    shared across slots — the +1 is the scratch page inactive slots
    write/gather through (serve.paging). State leaves (SSM/conv) keep
    their per-slot (L, n_slots, ...) layout: they carry no time dim, so
    paging buys them nothing.
    """
    pool = n_pages + 1

    def one(_):
        if cfg.mixer == "attn":
            c = L.attn_paged_cache_init(cfg, pool, page_size, dtype)
            return {"k": shard(c["k"], "kv_pages"),
                    "v": shard(c["v"], "kv_pages")}
        if cfg.mixer == "mla":
            c = L.mla_paged_cache_init(cfg, pool, page_size, dtype)
            return {"c_kv": shard(c["c_kv"], "mla_pages"),
                    "k_rope": c["k_rope"]}
        if cfg.mixer == "ssd":
            return L.ssd_cache_init(cfg, n_slots, dtype)
        if cfg.mixer == "hybrid":
            c = L.attn_paged_cache_init(cfg, pool, page_size, dtype)
            return {"attn": {"k": shard(c["k"], "kv_pages"),
                             "v": shard(c["v"], "kv_pages")},
                    "ssd": L.ssd_cache_init(cfg, n_slots, dtype)}
        raise ValueError(cfg.mixer)

    return jax.vmap(one)(jnp.arange(cfg.n_layers))


def abstract_paged_cache(cfg: ModelConfig, n_pages: int, page_size: int,
                         n_slots: int, dtype) -> PyTree:
    return jax.eval_shape(
        lambda: init_paged_cache(cfg, n_pages, page_size, n_slots, dtype))
