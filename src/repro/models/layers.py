"""Layer primitives for the unified decoder.

Everything is hand-rolled JAX (no flax): each sublayer is an
``init_*(key, cfg) -> params`` plus ``*_apply(params, x, ...) -> y`` pair.
Numerics: params in cfg.dtype (bf16 by default), matmul accumulation and
softmax/norms in fp32.

Attention is *blockwise* (flash-style, pure JAX): an outer scan over query
chunks and an inner scan over KV chunks with an online softmax — O(S)
memory so prefill_32k never materializes an (S, S) score matrix. Causal
masking is applied per chunk pair; sliding-window attention restricts the
inner scan to the static neighbouring chunks (used by hymba @ long_500k).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.api import replicated, shard
from .config import ModelConfig

PyTree = Any
F32 = jnp.float32


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, in_dim: int, out_dim: int, dtype) -> jax.Array:
    s = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * s).astype(dtype)


def dense(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.einsum("...i,io->...o", x, w.astype(x.dtype))


def csb_dense(x: jax.Array, lin) -> jax.Array:
    """A projection through a ``core.CSBLinear`` — the CSB-pruned twin
    of :func:`dense`.

    When a ``use_rules`` scope with a non-trivial "model" mesh axis is
    active, the frozen weight's block grid is partitioned over that
    axis by engine cycle cost (``dist.csb_partition``) and executed via
    the shard_map kernel (``kernels.csb_sharded``); otherwise this is
    the plain single-device Pallas path. Either way the output is
    tagged with the "residual" layout so downstream sublayers see the
    same sharding a dense projection would produce.
    """
    return shard(lin(x).astype(x.dtype), "residual")


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int, dtype) -> jax.Array:
    return jnp.ones((dim,), dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(F32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=F32) / half))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); pos: (S,) or (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = pos[..., :, None].astype(F32) * freqs        # (..., S, D/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise causal attention (flash-style, pure JAX)
# ---------------------------------------------------------------------------

def _attend_chunk(q, k, v, mask, scale):
    """q,k:(B,Cq,H,D) v:(B,Ck,KV,Dv) mask:(Cq,Ck) -> unnormalized o, m, l.

    v's head dim may differ from q/k's (MLA).
    """
    b, cq, h, d = q.shape
    kv = k.shape[2]
    rep = h // kv
    qh = q.reshape(b, cq, kv, rep, d)
    # fp32 accumulation WITHOUT materializing fp32 copies of K/V
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qh, k,
                   preferred_element_type=F32)
    s = s * scale
    # -1e30 (not -inf) keeps fully-masked rows NaN-free in fwd and bwd.
    s = jnp.where(mask[None, None, None], s, -1e30)
    m = jnp.max(s, axis=-1)                             # (B,G,R,Cq)
    p = jnp.exp(s - jax.lax.stop_gradient(m)[..., None])
    p = jnp.where(mask[None, None, None], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bgrqk,bkgd->bgrqd", p.astype(v.dtype), v,
                   preferred_element_type=F32)
    return o, m, l


def blockwise_attention(
    q: jax.Array,           # (B, S, H, D)
    k: jax.Array,           # (B, T, KV, D)
    v: jax.Array,
    *,
    q_offset: jax.Array | int = 0,   # absolute position of q[0]
    causal: bool = True,
    window: int | None = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Online-softmax attention; memory O(S * chunk). Returns (B,S,H,D)."""
    b, s, h, d = q.shape
    t = k.shape[1]
    kv = k.shape[2]
    dv = v.shape[3]
    scale = 1.0 / math.sqrt(d)
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, t)
    # pad to chunk multiples
    sp = -(-s // q_chunk) * q_chunk
    tp = -(-t // kv_chunk) * kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
    nq, nk = sp // q_chunk, tp // kv_chunk

    qs = qp.reshape(b, nq, q_chunk, h, d).transpose(1, 0, 2, 3, 4)
    ks = kp.reshape(b, nk, kv_chunk, kv, d).transpose(1, 0, 2, 3, 4)
    vs = vp.reshape(b, nk, kv_chunk, kv, dv).transpose(1, 0, 2, 3, 4)

    q_offset = jnp.asarray(q_offset, jnp.int32)

    def q_step(_, qi_and_idx):
        qi, iq = qi_and_idx
        qpos = q_offset + iq * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki_and_idx):
            o, m, l = carry
            (ki, vi), ik = ki_and_idx
            kpos = ik * kv_chunk + jnp.arange(kv_chunk)
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window is not None:
                mask &= qpos[:, None] - kpos[None, :] < window
            mask &= (kpos < t)[None, :]
            oi, mi, li = _attend_chunk(qi, ki, vi, mask, scale)
            m_new = jnp.maximum(m, mi)
            a_old = jnp.exp(m - m_new)
            a_new = jnp.exp(mi - m_new)
            o = o * a_old[..., None] + oi * a_new[..., None]
            l = l * a_old + li * a_new
            return (o, m_new, l), None

        rep = h // kv
        o0 = jnp.zeros((b, kv, rep, q_chunk, dv), F32)
        m0 = jnp.full((b, kv, rep, q_chunk), -1e30, F32)
        l0 = jnp.zeros((b, kv, rep, q_chunk), F32)
        (o, m, l), _ = jax.lax.scan(
            kv_step, (o0, m0, l0), ((ks, vs), jnp.arange(nk)))
        o = o / jnp.maximum(l[..., None], 1e-30)
        # (B,G,R,Cq,Dv) -> (B,Cq,H,Dv)
        o = o.transpose(0, 3, 1, 2, 4).reshape(b, q_chunk, h, dv)
        return None, o.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (qs, jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, sp, h, dv)
    return out[:, :s]


def context_attention(q, k, v, ctx_k, ctx_v, start, *, window=None):
    """Suffix attention against a fixed, already-computed prefix context
    (the prefix-cache partial prefill).

    ``q``/``k``/``v`` are the suffix projections, (B, S, H|KV, D), at
    absolute positions ``start + arange(S)``; ``ctx_k``/``ctx_v`` are
    cached prefix KV, (B, C, KV, D), of which only positions ``< start``
    are real (``start`` is traced — the context rides padded to a fixed
    page-aligned width, padding masked out here).

    Single-chunk mirror of :func:`blockwise_attention`'s math: the same
    einsum forms, -1e30 masking, fp32 accumulation and l-normalization,
    evaluated over ``concat([ctx, suffix])`` keys in ONE chunk. Because a
    masked column contributes exactly -1e30 to the max and exactly 0.0
    to the sums, a suffix row's output is bit-identical to what the full
    single-chunk prefill computes for that row — the engine's
    token-parity guarantee rests on this (and therefore on prompts
    fitting one kv chunk; serve prompts are far below the 1024 default).
    """
    b, s, h, d = q.shape
    c = ctx_k.shape[1]
    scale = 1.0 / math.sqrt(d)
    # replicated(...): concatenating the (replicated) cached context onto
    # the suffix projections re-chunks the time axis, the same layout
    # transition dist.api.shard documents as miscompiling on the CPU
    # SPMD backend (observed as on!=off token drift on 2x4 meshes).
    # Context widths are a handful of pages — replication is free.
    # Scope matters: pinning q as well flips the drift onto the MLA
    # path (both 1x8 and 2x4) — pin exactly the concat operands.
    kk = jnp.concatenate([replicated(ctx_k).astype(k.dtype),
                          replicated(k)], axis=1)
    vv = jnp.concatenate([replicated(ctx_v).astype(v.dtype),
                          replicated(v)], axis=1)
    start = jnp.asarray(start, jnp.int32)
    qpos = start + jnp.arange(s)
    kpos = jnp.concatenate([jnp.arange(c), start + jnp.arange(s)])
    valid = jnp.concatenate(
        [jnp.arange(c) < start, jnp.ones((s,), bool)])
    mask = (qpos[:, None] >= kpos[None, :]) & valid[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    o, _, l = _attend_chunk(q, kk, vv, mask, scale)
    o = o / jnp.maximum(l[..., None], 1e-30)
    dv = vv.shape[3]
    o = o.transpose(0, 3, 1, 2, 4).reshape(b, s, h, dv)
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention sublayer
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig) -> PyTree:
    dt = _dtype(cfg)
    d, hd, nh, nkv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, nh * hd, dt),
        "wk": dense_init(ks[1], d, nkv * hd, dt),
        "wv": dense_init(ks[2], d, nkv * hd, dt),
        "wo": dense_init(ks[3], nh * hd, d, dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dt)
        p["k_norm"] = rmsnorm_init(hd, dt)
    return p


def attn_qkv(p, x, cfg: ModelConfig, pos):
    b, s, _ = x.shape
    hd, nh, nkv = cfg.hd, cfg.n_heads, cfg.n_kv
    # fallback="replicate" on all three: q/k/v must not inherit the
    # projection weight's output-dim sharding through the reshape — the
    # resulting layout transitions (rope's rotate-half split/concat for
    # q/k, the chunked attention scans for v; each observed empirically)
    # miscompile on the CPU SPMD backend — see dist.api.shard
    q = shard(dense(x, p["wq"]).reshape(b, s, nh, hd), "attn_q",
              fallback="replicate")
    k = shard(dense(x, p["wk"]).reshape(b, s, nkv, hd), "attn_kv",
              fallback="replicate")
    v = shard(dense(x, p["wv"]).reshape(b, s, nkv, hd), "attn_kv",
              fallback="replicate")
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    return q, k, v


def attn_apply(p, x, cfg: ModelConfig, *, window=None):
    """Full (prefill/train) self-attention."""
    b, s, _ = x.shape
    pos = jnp.arange(s)
    q, k, v = attn_qkv(p, x, cfg, pos)
    o = blockwise_attention(q, k, v, causal=True, window=window)
    return dense(o.reshape(b, s, -1), p["wo"])


def _decode_pos(pos, s: int):
    """Normalize a decode position to (query_pos, row_pos).

    ``pos`` may be a scalar (whole batch at one depth — the fixed-batch
    serve loop) or a (B,) vector (continuous batching: each slot decodes
    at its own depth). Returns the rope positions for the s query tokens
    — (s,) or (B, s) — and ``row_pos`` shaped (1,) or (B,) for per-row
    cache masking.
    """
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        return pos + jnp.arange(s), pos[None]
    return pos[:, None] + jnp.arange(s), pos


def _cache_write(buf, new, pos):
    """Write ``new`` (B, s, ...) into ``buf`` (B, T, ...) at time ``pos``
    (scalar, or (B,) with a per-row write offset)."""
    new = new.astype(buf.dtype)
    if jnp.ndim(pos) == 0:
        return jax.lax.dynamic_update_slice_in_dim(buf, new, pos, 1)
    return jax.vmap(
        lambda b_, n_, p_: jax.lax.dynamic_update_slice_in_dim(b_, n_, p_, 0)
    )(buf, new, pos)


def _decode_mask(t: int, row_pos, s: int, window):
    """Per-query causal decode mask, (1|B, S, T): query i (absolute
    position ``row_pos + i``) sees keys at ``kpos <= row_pos + i``. For
    s == 1 this is the classic single-token decode mask; s > 1 is the
    speculative verify step, where later draft positions may attend
    earlier drafts written this same step but never the reverse."""
    kpos = jnp.arange(t)
    qp = row_pos[:, None] + jnp.arange(s)             # (1|B, S)
    mask = kpos[None, None, :] <= qp[:, :, None]      # (1|B, S, T)
    if window is not None:
        mask &= kpos[None, None, :] > qp[:, :, None] - window
    return mask


def attn_decode(p, x, cfg: ModelConfig, cache, pos):
    """Decode step. cache: {k:(B,T,KV,D), v:...}; pos: scalar or (B,)
    per-sequence positions (continuous batching). x may carry s > 1
    tokens (speculative verification): token i lands at ``pos + i`` and
    attends causally through the batch it rides in."""
    b, s, _ = x.shape
    qpos, row_pos = _decode_pos(pos, s)
    q, k, v = attn_qkv(p, x, cfg, qpos)
    ck = _cache_write(cache["k"], k, pos)
    cv = _cache_write(cache["v"], v, pos)
    t = ck.shape[1]
    kv = ck.shape[2]
    rep = cfg.n_heads // kv
    qh = q.reshape(b, s, kv, rep, cfg.hd)
    sc = jnp.einsum("bqgrd,bkgd->bgrqk", qh.astype(ck.dtype), ck,
                    preferred_element_type=F32)
    sc = sc / math.sqrt(cfg.hd)
    mask = _decode_mask(t, row_pos, s, cfg.window)     # (1|B, S, T)
    sc = jnp.where(mask[:, None, None, :, :], sc, -1e30)
    pattn = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bgrqk,bkgd->bqgrd", pattn.astype(cv.dtype), cv,
                   preferred_element_type=F32)
    # pin before the row-parallel out-proj: wo's input-dim sharding
    # otherwise propagates backward through the softmax/einsum chain
    # inside the decode layer scan — involuntary-remat miscompile on
    # the CPU SPMD backend (see dist.api.shard), observed as O(1)
    # logit drift whenever the head count cannot split the model axis
    o = shard(o.reshape(b, s, -1).astype(x.dtype), "residual",
              fallback="replicate")
    return dense(o, p["wo"]),{"k": ck, "v": cv}


def attn_cache_init(cfg: ModelConfig, batch: int, t: int, dtype) -> PyTree:
    return {
        "k": jnp.zeros((batch, t, cfg.n_kv, cfg.hd), dtype),
        "v": jnp.zeros((batch, t, cfg.n_kv, cfg.hd), dtype),
    }


# ---------------------------------------------------------------------------
# Paged cache primitives (serve.paging owns the page table; this is the
# device half: position -> (page, offset) indirection on pool-shaped
# cache leaves (N_pages, page_size, ...) shared by all decode slots)
# ---------------------------------------------------------------------------

def paged_write(pool: jax.Array, new: jax.Array, pos,
                page_table: jax.Array) -> jax.Array:
    """Scatter one decode step's ``new`` (B, 1, ...) into ``pool``
    (N, P, ...) at each row's (page, offset) for time position ``pos``
    (scalar or (B,)).

    Rows whose position is not mapped (inactive slots) carry the scratch
    page in ``page_table`` (serve.paging.PagePool.device_table), so the
    scatter needs no mask; live slots own disjoint pages by allocator
    invariant, so writes never collide. ``new`` may carry s > 1 tokens
    (speculative verification): token i scatters to position ``pos + i``.
    """
    b, s = new.shape[0], new.shape[1]
    psz = pool.shape[1]
    posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    posv = posv[:, None] + jnp.arange(s)               # (B, S)
    logical = jnp.clip(posv // psz, 0, page_table.shape[1] - 1)
    page = jnp.take_along_axis(page_table, logical, axis=1)
    return pool.at[page, posv % psz].set(new.astype(pool.dtype))


def paged_gather(pool: jax.Array, page_table: jax.Array) -> jax.Array:
    """Materialize each slot's logical time extent from the pool:
    (N, P, ...) gathered through (B, max_pages) -> (B, max_pages*P, ...).

    Unmapped entries gather the scratch page; its (finite garbage)
    values sit at logical positions beyond the slot's decode position
    and the ``kpos <= pos`` mask zeroes them out of the softmax exactly
    (exp(-1e30 - m) underflows to 0 in f32).
    """
    b, mp = page_table.shape
    g = pool[page_table]                       # (B, max_pages, P, ...)
    return g.reshape((b, mp * pool.shape[1]) + pool.shape[2:])


def attn_decode_paged(p, x, cfg: ModelConfig, cache, pos, page_table,
                      use_kernel: bool = False):
    """One-token decode through the paged KV pool. cache:
    {k: (N, P, KV, D), v: ...}; ``page_table``: (B, max_pages) int32.

    ``use_kernel=True`` routes the attention through the Pallas
    paged-attention kernel (``kernels.paged_attn``), which walks the
    page table in-kernel instead of materializing the (B, max_pages*P)
    gather; tokens match the gather path. The kernel path is single-query
    (s == 1); multi-token verify steps take the gather path."""
    b, s, _ = x.shape
    qpos, row_pos = _decode_pos(pos, s)
    q, k, v = attn_qkv(p, x, cfg, qpos)
    ck = paged_write(cache["k"], k, pos, page_table)
    cv = paged_write(cache["v"], v, pos, page_table)
    if use_kernel and s == 1:
        from repro.kernels.paged_attn import paged_attn_decode
        # replicated(...): the kernel's grid loop must stay off GSPMD's
        # guessed layouts (see dist.api.replicated) — pools are small
        # relative to the contiguous cache they replace, and every slot
        # may address every page anyway
        o = paged_attn_decode(replicated(q[:, 0]), replicated(ck),
                              replicated(cv), replicated(page_table),
                              replicated(row_pos),
                              scale=1.0 / math.sqrt(cfg.hd),
                              window=cfg.window)
        o = replicated(o).reshape(b, s, -1).astype(x.dtype)
        return dense(o, p["wo"]), {"k": ck, "v": cv}
    kg = paged_gather(ck, page_table)          # (B, T, KV, D)
    vg = paged_gather(cv, page_table)
    t = kg.shape[1]
    kv = kg.shape[2]
    rep = cfg.n_heads // kv
    qh = q.reshape(b, s, kv, rep, cfg.hd)
    sc = jnp.einsum("bqgrd,bkgd->bgrqk", qh.astype(kg.dtype), kg,
                    preferred_element_type=F32)
    sc = sc / math.sqrt(cfg.hd)
    mask = _decode_mask(t, row_pos, s, cfg.window)     # (1|B, S, T)
    sc = jnp.where(mask[:, None, None, :, :], sc, -1e30)
    pattn = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bgrqk,bkgd->bqgrd", pattn.astype(vg.dtype), vg,
                   preferred_element_type=F32)
    # pin before the row-parallel out-proj: wo's input-dim sharding
    # otherwise propagates backward through the softmax/einsum chain
    # inside the decode layer scan — involuntary-remat miscompile on
    # the CPU SPMD backend (see dist.api.shard), observed as O(1)
    # logit drift whenever the head count cannot split the model axis
    o = shard(o.reshape(b, s, -1).astype(x.dtype), "residual",
              fallback="replicate")
    return dense(o, p["wo"]),{"k": ck, "v": cv}


def attn_paged_cache_init(cfg: ModelConfig, n_pages: int, page_size: int,
                          dtype) -> PyTree:
    """Pool-shaped KV cache. ``n_pages`` INCLUDES the scratch page the
    allocator points inactive slots at (pass pool.n_pages + 1)."""
    return {
        "k": jnp.zeros((n_pages, page_size, cfg.n_kv, cfg.hd), dtype),
        "v": jnp.zeros((n_pages, page_size, cfg.n_kv, cfg.hd), dtype),
    }


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig) -> PyTree:
    dt = _dtype(cfg)
    d, hd, nh = cfg.d_model, cfg.hd, cfg.n_heads
    rd, kvl, ql = cfg.rope_head_dim, cfg.kv_lora, cfg.q_lora
    ks = jax.random.split(key, 7)
    return {
        "wq_a": dense_init(ks[0], d, ql, dt),              # q down
        "q_norm": rmsnorm_init(ql, dt),
        "wq_b": dense_init(ks[1], ql, nh * (hd + rd), dt), # q up (nope+rope)
        "wkv_a": dense_init(ks[2], d, kvl + rd, dt),       # kv down + k_rope
        "kv_norm": rmsnorm_init(kvl, dt),
        "wk_b": dense_init(ks[3], kvl, nh * hd, dt),       # k up (nope)
        "wv_b": dense_init(ks[4], kvl, nh * hd, dt),       # v up
        "wo": dense_init(ks[5], nh * hd, d, dt),
    }


def _mla_qkv(p, x, cfg: ModelConfig, pos):
    b, s, _ = x.shape
    hd, nh, rd = cfg.hd, cfg.n_heads, cfg.rope_head_dim
    qa = rmsnorm(dense(x, p["wq_a"]), p["q_norm"])
    qb = dense(qa, p["wq_b"]).reshape(b, s, nh, hd + rd)
    q_nope, q_rope = qb[..., :hd], qb[..., hd:]
    # same rope layout guard as attn_qkv (see dist.api.shard)
    q_rope = shard(q_rope, "attn_q", fallback="replicate")
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)

    kva = dense(x, p["wkv_a"])
    c_kv = rmsnorm(kva[..., : cfg.kv_lora], p["kv_norm"])   # (B,S,kvl)
    k_rope = kva[..., cfg.kv_lora:].reshape(b, s, 1, rd)
    k_rope = shard(k_rope, "attn_kv", fallback="replicate")
    k_rope = apply_rope(k_rope, pos, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope


def mla_apply(p, x, cfg: ModelConfig, *, window=None):
    b, s, _ = x.shape
    hd, nh, rd = cfg.hd, cfg.n_heads, cfg.rope_head_dim
    pos = jnp.arange(s)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, cfg, pos)
    k_nope = dense(c_kv, p["wk_b"]).reshape(b, s, nh, hd)
    v = dense(c_kv, p["wv_b"]).reshape(b, s, nh, hd)
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope, (b, s, nh, rd))], -1)
    o = blockwise_attention(q, k, v, causal=True, window=window)
    return dense(o.reshape(b, s, -1), p["wo"])


def mla_decode(p, x, cfg: ModelConfig, cache, pos):
    """Decode with the *compressed* cache (c_kv + k_rope) — MLA's point.
    ``pos``: scalar, or (B,) per-sequence positions."""
    b, s, _ = x.shape
    hd, nh, rd = cfg.hd, cfg.n_heads, cfg.rope_head_dim
    qpos, row_pos = _decode_pos(pos, s)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, cfg, qpos)
    cc = _cache_write(cache["c_kv"], c_kv, pos)
    cr = _cache_write(cache["k_rope"], k_rope[:, :, 0], pos)
    t = cc.shape[1]
    # absorb k up-projection into q (the MLA decode trick):
    # score = q_nope . (W_kb c) = (W_kb^T q_nope) . c
    wkb = p["wk_b"].reshape(cfg.kv_lora, nh, hd)
    q_c = jnp.einsum("bqhd,lhd->bqhl", q_nope.astype(wkb.dtype), wkb,
                     preferred_element_type=F32)
    s_c = jnp.einsum("bqhl,bkl->bhqk", q_c.astype(cc.dtype), cc,
                     preferred_element_type=F32)
    s_r = jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(cr.dtype), cr,
                     preferred_element_type=F32)
    sc = (s_c + s_r) / math.sqrt(hd + rd)
    mask = _decode_mask(t, row_pos, s, None)           # (1|B, S, T)
    sc = jnp.where(mask[:, None, :, :], sc, -1e30)
    pattn = jax.nn.softmax(sc, axis=-1)
    o_c = jnp.einsum("bhqk,bkl->bqhl", pattn.astype(cc.dtype), cc,
                     preferred_element_type=F32)          # (B,s,H,kvl)
    wvb = p["wv_b"].reshape(cfg.kv_lora, nh, hd)
    o = jnp.einsum("bqhl,lhd->bqhd", o_c.astype(wvb.dtype), wvb,
                   preferred_element_type=F32)
    # pin before the row-parallel out-proj: wo's input-dim sharding
    # otherwise propagates backward through the softmax/einsum chain
    # inside the decode layer scan — involuntary-remat miscompile on
    # the CPU SPMD backend (see dist.api.shard), observed as O(1)
    # logit drift whenever the head count cannot split the model axis
    o = shard(o.reshape(b, s, -1).astype(x.dtype), "residual",
              fallback="replicate")
    return dense(o, p["wo"]),{"c_kv": cc, "k_rope": cr}


def mla_cache_init(cfg: ModelConfig, batch: int, t: int, dtype) -> PyTree:
    return {
        "c_kv": jnp.zeros((batch, t, cfg.kv_lora), dtype),
        "k_rope": jnp.zeros((batch, t, cfg.rope_head_dim), dtype),
    }


def mla_decode_paged(p, x, cfg: ModelConfig, cache, pos, page_table,
                     use_kernel: bool = False):
    """MLA decode through paged compressed-KV pools. cache:
    {c_kv: (N, P, kvl), k_rope: (N, P, rd)}.

    ``use_kernel=True`` runs the absorbed-q attention through the Pallas
    paged-attention kernel: one KV group, the compressed latent as both
    key and value, and the rope term as the kernel's second score dot —
    no (B, max_pages*P) gather materialization."""
    b, s, _ = x.shape
    hd, nh, rd = cfg.hd, cfg.n_heads, cfg.rope_head_dim
    qpos, row_pos = _decode_pos(pos, s)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, cfg, qpos)
    cc_pool = paged_write(cache["c_kv"], c_kv, pos, page_table)
    cr_pool = paged_write(cache["k_rope"], k_rope[:, :, 0], pos, page_table)
    if use_kernel and s == 1:
        from repro.kernels.paged_attn import paged_attn_decode
        wkb = p["wk_b"].reshape(cfg.kv_lora, nh, hd)
        q_c = jnp.einsum("bqhd,lhd->bqhl", q_nope.astype(wkb.dtype), wkb,
                         preferred_element_type=F32)
        cc_r = replicated(cc_pool[:, :, None, :])
        o_c = paged_attn_decode(
            replicated(q_c[:, 0]), cc_r, cc_r,
            replicated(page_table), replicated(row_pos),
            scale=1.0 / math.sqrt(hd + rd),
            q2=replicated(q_rope[:, 0]),
            k2_pool=replicated(cr_pool[:, :, None, :]))
        o_c = replicated(o_c)
        wvb = p["wv_b"].reshape(cfg.kv_lora, nh, hd)
        o = jnp.einsum("bqhl,lhd->bqhd", o_c[:, None].astype(wvb.dtype),
                       wvb, preferred_element_type=F32)
        o = o.reshape(b, s, -1).astype(x.dtype)
        return dense(o, p["wo"]), {"c_kv": cc_pool, "k_rope": cr_pool}
    cc = paged_gather(cc_pool, page_table)     # (B, T, kvl)
    cr = paged_gather(cr_pool, page_table)     # (B, T, rd)
    t = cc.shape[1]
    wkb = p["wk_b"].reshape(cfg.kv_lora, nh, hd)
    q_c = jnp.einsum("bqhd,lhd->bqhl", q_nope.astype(wkb.dtype), wkb,
                     preferred_element_type=F32)
    s_c = jnp.einsum("bqhl,bkl->bhqk", q_c.astype(cc.dtype), cc,
                     preferred_element_type=F32)
    s_r = jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(cr.dtype), cr,
                     preferred_element_type=F32)
    sc = (s_c + s_r) / math.sqrt(hd + rd)
    mask = _decode_mask(t, row_pos, s, None)           # (1|B, S, T)
    sc = jnp.where(mask[:, None, :, :], sc, -1e30)
    pattn = jax.nn.softmax(sc, axis=-1)
    o_c = jnp.einsum("bhqk,bkl->bqhl", pattn.astype(cc.dtype), cc,
                     preferred_element_type=F32)
    wvb = p["wv_b"].reshape(cfg.kv_lora, nh, hd)
    o = jnp.einsum("bqhl,lhd->bqhd", o_c.astype(wvb.dtype), wvb,
                   preferred_element_type=F32)
    # pin before the row-parallel out-proj: wo's input-dim sharding
    # otherwise propagates backward through the softmax/einsum chain
    # inside the decode layer scan — involuntary-remat miscompile on
    # the CPU SPMD backend (see dist.api.shard), observed as O(1)
    # logit drift whenever the head count cannot split the model axis
    o = shard(o.reshape(b, s, -1).astype(x.dtype), "residual",
              fallback="replicate")
    return dense(o, p["wo"]),{"c_kv": cc_pool, "k_rope": cr_pool}


def mla_paged_cache_init(cfg: ModelConfig, n_pages: int, page_size: int,
                         dtype) -> PyTree:
    return {
        "c_kv": jnp.zeros((n_pages, page_size, cfg.kv_lora), dtype),
        "k_rope": jnp.zeros((n_pages, page_size, cfg.rope_head_dim),
                            dtype),
    }


# ---------------------------------------------------------------------------
# Gated MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None) -> PyTree:
    dt = _dtype(cfg)
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d, ff, dt),
        "w_up": dense_init(ks[1], d, ff, dt),
        "w_down": dense_init(ks[2], ff, d, dt),
    }


def mlp_apply(p, x, cfg: ModelConfig):
    g = dense(x, p["w_gate"])
    act = jax.nn.gelu(g) if cfg.ffn == "geglu" else jax.nn.silu(g)
    return dense(act * dense(x, p["w_up"]), p["w_down"])


# ---------------------------------------------------------------------------
# MoE (top-k, shared experts, capacity-dropped chunked dispatch)
# ---------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig) -> PyTree:
    dt = _dtype(cfg)
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.moe_dff
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, ff)) * s).astype(dt),
        "w_up": (jax.random.normal(ks[2], (e, d, ff)) * s).astype(dt),
        "w_down": (jax.random.normal(ks[3], (e, ff, d))
                   * (1.0 / math.sqrt(ff))).astype(dt),
    }
    if cfg.n_shared:
        p["shared"] = mlp_init(ks[4], cfg, cfg.n_shared * cfg.moe_dff)
    return p


def moe_apply(p, x, cfg: ModelConfig):
    """x: (B, S, d). GShard-style dispatch over G token groups.

    Groups are sharded over the dp axes (rule "moe_groups"), so the
    position cumsum and both dispatch einsums are shard-LOCAL; expert
    tensors are sharded over the model axis (rule "moe_experts") so
    expert FFNs are local too. The only collective left is the combine
    psum back into the (dp-sharded) token layout — the structure a real
    MoE pod run wants. (The pre-hillclimb version scanned chunks over an
    unsharded token axis: cross-device cumsum -> collective-permute
    chains + per-chunk all-reduces; see EXPERIMENTS.md §Perf.)
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(b * s, d)
    t = xt.shape[0]
    chunk = min(cfg.moe_chunk, t)
    tp = -(-t // chunk) * chunk
    xt = jnp.pad(xt, ((0, tp - t), (0, 0)))
    g = tp // chunk
    cap = max(int(chunk * k / e * cfg.capacity_factor), 4)
    cd = x.dtype

    xg = shard(xt.reshape(g, chunk, d), "moe_groups")         # (G, C, d)
    logits = dense(xg.astype(F32), p["router"])               # (G, C, E)
    probs = jax.nn.softmax(logits, -1)
    gate_vals, idx = jax.lax.top_k(probs, k)                  # (G, C, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(idx, e, dtype=F32)                # (G, C, k, E)
    flat = onehot.reshape(g, chunk * k, e)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(g, chunk, k, e)
    keep = (pos < cap) & (onehot > 0)

    disp = jnp.zeros((g, chunk, e, cap), cd)
    comb = jnp.zeros((g, chunk, e, cap), cd)
    for kk in range(k):
        sel = onehot[:, :, kk] * keep[:, :, kk].astype(F32)   # (G, C, E)
        p_oh = jax.nn.one_hot(
            pos[:, :, kk].astype(jnp.int32), cap, dtype=F32)  # (G, C, E, cap)
        d_k = sel[..., None] * p_oh
        disp = disp + d_k.astype(cd)
        comb = comb + (gate_vals[:, :, kk, None, None] * d_k).astype(cd)
    disp = shard(disp, "moe_dispatch")
    comb = shard(comb, "moe_dispatch")

    xe = jnp.einsum("gtec,gtd->gecd", disp, xg,
                    preferred_element_type=F32).astype(cd)
    xe = shard(xe, "moe_experts")
    h = jax.nn.silu(
        jnp.einsum("gecd,edf->gecf", xe, p["w_gate"],
                   preferred_element_type=F32)).astype(cd) \
        * jnp.einsum("gecd,edf->gecf", xe, p["w_up"],
                     preferred_element_type=F32).astype(cd)
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"],
                    preferred_element_type=F32).astype(cd)
    ye = shard(ye, "moe_experts")
    y = jnp.einsum("gtec,gecd->gtd", comb, ye,
                   preferred_element_type=F32)
    y = y.reshape(tp, d)[:t].reshape(b, s, d).astype(x.dtype)
    if cfg.n_shared:
        y = y + mlp_apply(p["shared"], x, cfg)
    return y


# ---------------------------------------------------------------------------
# Mamba2 SSD (state-space duality, chunked)
# ---------------------------------------------------------------------------

def ssd_init(key, cfg: ModelConfig) -> PyTree:
    dt = _dtype(cfg)
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.ssd_heads
    ks = jax.random.split(key, 6)
    conv_dim = di + 2 * n
    p = {
        "a_log": jnp.zeros((h,), F32),
        "d_skip": jnp.ones((h,), F32),
        "dt_bias": jnp.zeros((h,), F32),
        "out_norm": rmsnorm_init(di, dt),
        "w_out": dense_init(ks[2], di, d, dt),
    }
    if cfg.ssd_split_proj:
        p.update({
            "w_in_z": dense_init(ks[0], d, di, dt),
            "w_in_x": dense_init(ks[1], d, di, dt),
            "w_in_bc": dense_init(ks[3], d, 2 * n, dt),
            "w_in_dt": dense_init(ks[4], d, h, dt),
            "conv_w_x": (jax.random.normal(ks[5], (cfg.conv_k, di))
                         * 0.1).astype(dt),
            "conv_w_bc": (jax.random.normal(ks[5], (cfg.conv_k, 2 * n))
                          * 0.1).astype(dt),
        })
    else:
        p.update({
            "w_in": dense_init(ks[0], d, 2 * di + 2 * n + h, dt),
            "conv_w": (jax.random.normal(ks[1], (cfg.conv_k, conv_dim))
                       * 0.1).astype(dt),
        })
    return p


def _ssd_in_proj(p, x, cfg: ModelConfig):
    """Returns (z, conv_in, dt) where conv_in = [x, B, C]."""
    di, n = cfg.d_inner, cfg.d_state
    if cfg.ssd_split_proj:
        z = dense(x, p["w_in_z"])
        xin = dense(x, p["w_in_x"])
        bcmat = dense(x, p["w_in_bc"])
        dtp = dense(x, p["w_in_dt"])
        return z, jnp.concatenate([xin, bcmat], -1), dtp
    zxbcdt = dense(x, p["w_in"])
    z, xin, bmat, cmat, dtp = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    return z, jnp.concatenate([xin, bmat, cmat], -1), dtp


def _ssd_conv_weight(p, cfg: ModelConfig):
    if cfg.ssd_split_proj:
        return jnp.concatenate([p["conv_w_x"], p["conv_w_bc"]], -1)
    return p["conv_w"]


def _segsum(x: jax.Array) -> jax.Array:
    """x: (..., L) -> (..., L, L) lower-tri cumulative sums for decay."""
    l = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    ii = jnp.arange(l)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(x, dt, a, bmat, cmat, chunk: int):
    """SSD chunked scan (Dao & Gu 2024).

    x: (B,S,H,P) dt: (B,S,H) a: (H,) neg-decay, b,c: (B,S,N).
    Returns y: (B,S,H,P), final state (B,H,P,N).
    """
    bsz, s, h, p_dim = x.shape
    n = bmat.shape[-1]
    sp = -(-s // chunk) * chunk
    pad = sp - s
    x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
    cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    nc = sp // chunk

    xr = x.reshape(bsz, nc, chunk, h, p_dim)
    dtr = dt.reshape(bsz, nc, chunk, h)
    br = bmat.reshape(bsz, nc, chunk, n)
    cr = cmat.reshape(bsz, nc, chunk, n)

    da = dtr * a[None, None, None, :]            # (B,C,L,H) decay logs (<=0)
    dax = xr * dtr[..., None]                    # dt-weighted inputs

    # intra-chunk (quadratic within chunk)
    lmat = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))       # (B,C,H,L,L)
    scores = jnp.einsum("bcln,bckn->bclk", cr, br)          # (B,C,L,L)
    y_diag = jnp.einsum("bclk,bchlk,bckhp->bclhp",
                        scores, lmat, dax)

    # chunk-final states
    decay_end = jnp.exp(jnp.cumsum(da[..., ::-1, :], axis=2)[..., ::-1, :]
                        - da)                                # sum_{l'>l}
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", br, decay_end, dax)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(jnp.sum(da, axis=2))               # (B,C,H)

    def step(carry, inp):
        st_in = carry
        st_new, dec = inp
        st = st_in * dec[..., None, None] + st_new
        return st, st_in                                     # emit state *before* chunk

    st0 = jnp.zeros((bsz, h, p_dim, n), F32)
    final, prior = jax.lax.scan(
        step,
        st0,
        (states.transpose(1, 0, 2, 3, 4).astype(F32),
         chunk_decay.transpose(1, 0, 2)),
    )
    prior = prior.transpose(1, 0, 2, 3, 4)                   # (B,C,H,P,N)

    # off-diagonal contribution: carried state into each position
    decay_in = jnp.exp(jnp.cumsum(da, axis=2))               # decay from chunk start
    y_off = jnp.einsum("bcln,bclh,bchpn->bclhp",
                       cr, decay_in, prior)

    y = (y_diag + y_off).reshape(bsz, sp, h, p_dim)[:, :s]
    return y.astype(x.dtype), final


def ssd_block_apply(p, x, cfg: ModelConfig, conv_state=None, ssm_state=None,
                    decode: bool = False):
    """Full mamba2 block: in-proj -> conv -> SSD -> gated norm -> out-proj.

    Train/prefill: decode=False, states None -> returns y only.
    Decode: x is (B,1,d); states updated, returns (y, conv_state, ssm_state).
    """
    bsz, s, _ = x.shape
    di, n, h, pd = cfg.d_inner, cfg.d_state, cfg.ssd_heads, cfg.ssd_headdim
    z, conv_in, dt = _ssd_in_proj(p, x, cfg)                 # (B,S,conv_dim)
    # Anchor the SSD streams to an explicit batch-only layout (pinned
    # replicated when the batch cannot split). Without the anchor the
    # in-proj weight's output-dim sharding propagates into the conv
    # shifts / head reshapes / chunked-scan cumsums below, and the SPMD
    # partitioner reassociates those reductions (reduce-window ->
    # collective-permute chains in tools/hlo_diff.py) — observed to
    # change prefill logits by O(1), not just flip f32 ties, on the
    # 2x4 host mesh whenever batch < data-axis size. Same idiom as the
    # rope/attn_q pins in attn_apply.
    z = shard(z, "ssd_inner", fallback="replicate")
    conv_in = shard(conv_in, "ssd_inner", fallback="replicate")
    dt = shard(dt, "ssd_inner", fallback="replicate")
    cw = _ssd_conv_weight(p, cfg)

    if not decode:
        # causal depthwise conv via k shifted adds (k is tiny)
        k = cfg.conv_k
        conv = sum(
            jnp.pad(conv_in, ((0, 0), (k - 1 - i, 0), (0, 0)))[:, : s]
            * cw[i]
            for i in range(k)
        )
        new_conv_state = None
    else:
        # conv_state: (B, k-1, conv_dim) of the most recent inputs
        k = cfg.conv_k
        hist = jnp.concatenate([conv_state, conv_in], axis=1)  # (B,k,conv)
        conv = jnp.einsum("bkc,kc->bc", hist, cw)[:, None]
        new_conv_state = hist[:, 1:]

    # Re-anchor after the conv: ``conv_w`` is model-sharded on its
    # conv_dim (it is a plain >=2-D weight to the placement rules), so
    # the shifted-add / einsum above re-introduces a model split that
    # would otherwise flow into the chunked scan below.
    conv = shard(conv, "ssd_inner", fallback="replicate")
    conv = jax.nn.silu(conv)
    xc, bc, cc = jnp.split(conv, [di, di + n], axis=-1)
    xh = xc.reshape(bsz, s, h, pd)
    a = -jnp.exp(p["a_log"])                                  # (H,) < 0
    dt_full = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])  # (B,S,H)

    if not decode:
        y, final = ssd_scan(xh, dt_full, a, bc.astype(F32), cc.astype(F32),
                            cfg.ssd_chunk)
        new_ssm = final
    else:
        # single-step recurrence (update math in f32; state stored in
        # cfg.ssd_state_dtype — bf16 halves decode state traffic).
        # The state cache arrives model-sharded over heads
        # (dist.rules.cache_specs); pin the step replicated — GSPMD's
        # layout for the bh,bhp,bn->bhpn outer product otherwise hits
        # the involuntary-full-rematerialization transition (wrong
        # numerics on the CPU SPMD backend, see dist.api.replicated).
        st = replicated(ssm_state.astype(F32))                # (B,H,P,N)
        dt1 = dt_full[:, 0]                                   # (B,H)
        da = jnp.exp(dt1 * a[None, :])                        # (B,H)
        dbx = jnp.einsum("bh,bhp,bn->bhpn", dt1, xh[:, 0].astype(F32),
                         bc[:, 0].astype(F32))
        st = st * da[..., None, None] + dbx
        y = jnp.einsum("bn,bhpn->bhp", cc[:, 0].astype(F32), st)
        y = y[:, None].reshape(bsz, 1, h, pd)
        new_ssm = st.astype(ssm_state.dtype)
    y = y + xh.astype(F32) * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, di).astype(x.dtype)
    y = shard(y, "ssd_inner", fallback="replicate")
    y = rmsnorm(y, p["out_norm"]) * jax.nn.silu(z)
    out = dense(y, p["w_out"])
    if decode:
        return out, new_conv_state, new_ssm
    return out


def ssd_cache_init(cfg: ModelConfig, batch: int, dtype) -> PyTree:
    conv_dim = cfg.d_inner + 2 * cfg.d_state
    return {
        "conv": jnp.zeros((batch, cfg.conv_k - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, cfg.ssd_heads, cfg.ssd_headdim,
                          cfg.d_state), jnp.dtype(cfg.ssd_state_dtype)),
    }


# ---------------------------------------------------------------------------
# Hybrid (hymba): parallel attention + SSD heads, outputs fused
# ---------------------------------------------------------------------------

def hybrid_init(key, cfg: ModelConfig) -> PyTree:
    k1, k2 = jax.random.split(key)
    dt = _dtype(cfg)
    return {
        "attn": attn_init(k1, cfg),
        "ssd": ssd_init(k2, cfg),
        "attn_norm": rmsnorm_init(cfg.d_model, dt),
        "ssd_norm": rmsnorm_init(cfg.d_model, dt),
    }


def hybrid_apply(p, x, cfg: ModelConfig, *, window=None):
    ya = attn_apply(p["attn"], x, cfg, window=window)
    ys = ssd_block_apply(p["ssd"], x, cfg)
    return 0.5 * (rmsnorm(ya, p["attn_norm"]) + rmsnorm(ys, p["ssd_norm"]))


def hybrid_decode(p, x, cfg: ModelConfig, cache, pos):
    ya, attn_cache = attn_decode(p["attn"], x, cfg, cache["attn"], pos)
    ys, conv, ssm = ssd_block_apply(
        p["ssd"], x, cfg, conv_state=cache["ssd"]["conv"],
        ssm_state=cache["ssd"]["ssm"], decode=True)
    y = 0.5 * (rmsnorm(ya, p["attn_norm"]) + rmsnorm(ys, p["ssd_norm"]))
    return y, {"attn": attn_cache, "ssd": {"conv": conv, "ssm": ssm}}


def hybrid_decode_paged(p, x, cfg: ModelConfig, cache, pos, page_table,
                        use_kernel: bool = False):
    """Hybrid decode: the attention KV goes through the paged pool, the
    SSM/conv state (no time dim — nothing to page) stays per-slot."""
    ya, attn_cache = attn_decode_paged(p["attn"], x, cfg, cache["attn"],
                                       pos, page_table, use_kernel)
    ys, conv, ssm = ssd_block_apply(
        p["ssd"], x, cfg, conv_state=cache["ssd"]["conv"],
        ssm_state=cache["ssd"]["ssm"], decode=True)
    y = 0.5 * (rmsnorm(ya, p["attn_norm"]) + rmsnorm(ys, p["ssd_norm"]))
    return y, {"attn": attn_cache, "ssd": {"conv": conv, "ssm": ssm}}
