"""Paged KV/state cache: a block-pool allocator for serve slots.

The contiguous serve cache gives every decode slot the worst-case time
footprint (``n_slots x cache_len`` tokens) even when most requests are
short — the same waste the paper removes from *weights* by packing
irregular sparsity into fixed-size blocks (CSB §4). This module applies
that regular-block philosophy to *activations*: the cache becomes a pool
of fixed-size token **pages** shared by all slots, and each slot maps
its logical positions onto physical pages through a dense page table.

Design points (all jit-friendliness driven):

* The page table is a dense ``(n_slots, max_pages)`` int32 array —
  passed straight into the jitted decode step, no ragged host structure
  crosses the trace boundary. Free entries hold ``-1`` on the host;
  :meth:`device_table` maps them to a dedicated **scratch page** (index
  ``n_pages``, one extra physical page the pools allocate beyond the
  allocator's range) so inactive slots write/gather somewhere harmless
  without any masking inside the step.
* **Reservation-based admission**: a request reserves its own worst case
  (``ceil((prompt + max_new) / page_size)`` pages) when admitted, and
  physical pages are allocated lazily as the position advances
  (:meth:`ensure`). Admission is bounded by *per-request* need, not the
  global max length — mixed-length traces pack more concurrent requests
  into the same token budget than contiguous slots can — and a slot can
  never stall mid-decode waiting for a page (no deadlock by
  construction).
* Pages are freed the moment a request finishes (:meth:`release`),
  mid-decode, and immediately reusable. Freed pages are NOT zeroed: the
  decode mask (``kpos <= pos``) plus the write-before-unmask order means
  a successor can never attend a predecessor's stale KV (see
  serve.scheduler's eviction notes; per-slot SSM/conv state, which has
  no mask, is still zeroed by the engine).

Host-side only — the device half (paged write/gather, page-granular
insert) lives in ``models.layers`` / ``serve.scheduler``.
"""
from __future__ import annotations

import dataclasses


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` (0 tokens still needs 0 pages)."""
    return -(-max(n_tokens, 0) // page_size)


@dataclasses.dataclass
class PoolStats:
    """Running occupancy/fragmentation telemetry (sampled via tick())."""

    peak_pages: int = 0
    ticks: int = 0
    page_steps: int = 0          # sum over ticks of allocated pages
    frag_weighted: float = 0.0   # sum over ticks of internal-frag fraction

    def as_dict(self) -> dict:
        return {
            "peak_pages": self.peak_pages,
            "mean_pages": round(self.page_steps / self.ticks, 2)
            if self.ticks else 0.0,
            "internal_fragmentation": round(
                self.frag_weighted / self.ticks, 4) if self.ticks else 0.0,
        }


class PagePool:
    """Fixed-size token-page allocator behind the serve decode slots.

    ``n_pages``  — allocatable pool capacity (the scratch page the device
                   pools carry at index ``n_pages`` is NOT part of it).
    ``max_pages``— page-table width: the most pages one slot may ever
                   hold (``ceil(cache_len / page_size)``); bounds the
                   logical time extent the decode step gathers.
    """

    def __init__(self, page_size: int, n_pages: int, n_slots: int,
                 max_pages: int):
        if page_size < 1 or n_pages < 1 or n_slots < 1 or max_pages < 1:
            raise ValueError("page_size, n_pages, n_slots, max_pages "
                             "must all be >= 1")
        self.page_size = page_size
        self.n_pages = n_pages
        self.n_slots = n_slots
        self.max_pages = max_pages
        # LIFO free list: recently freed pages are reused first (their
        # device-side contents are hottest in cache)
        self._free: list[int] = list(range(n_pages - 1, -1, -1))
        self._table = [[-1] * max_pages for _ in range(n_slots)]
        self._n_alloc = [0] * n_slots     # physical pages held per slot
        self._reserved = [0] * n_slots    # admission reservation per slot
        self._tokens = [0] * n_slots      # tokens ensure()d per slot
        self.stats = PoolStats()
        self._dirty = True
        self._device_table = None

    # -- capacity / admission ------------------------------------------------
    def pages_needed(self, n_tokens: int) -> int:
        return pages_for(n_tokens, self.page_size)

    def reserved_total(self) -> int:
        return sum(self._reserved)

    def allocated_total(self) -> int:
        return self.n_pages - len(self._free)

    def available(self) -> int:
        """Pages admission may still promise (reservations included)."""
        return self.n_pages - self.reserved_total()

    def fits_ever(self, n_tokens: int) -> bool:
        """Could a request of this total length EVER be admitted?"""
        need = self.pages_needed(n_tokens)
        return need <= min(self.n_pages, self.max_pages)

    def can_admit(self, n_tokens: int) -> bool:
        need = self.pages_needed(n_tokens)
        return need <= self.max_pages and need <= self.available()

    # -- slot lifecycle ------------------------------------------------------
    def reserve(self, slot: int, n_tokens: int) -> None:
        """Admission: promise the slot its worst-case page count."""
        if self._reserved[slot]:
            raise RuntimeError(f"slot {slot} already holds a reservation")
        need = self.pages_needed(n_tokens)
        if not self.can_admit(n_tokens):
            raise RuntimeError(
                f"cannot reserve {need} pages for slot {slot}: "
                f"{self.available()} available, max_pages={self.max_pages}")
        self._reserved[slot] = need

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow the slot's allocation to cover ``n_tokens`` positions.
        Returns True when the page table changed (new pages mapped)."""
        need = self.pages_needed(n_tokens)
        if need > self._reserved[slot]:
            raise RuntimeError(
                f"slot {slot}: ensure({n_tokens}) needs {need} pages but "
                f"only {self._reserved[slot]} are reserved")
        self._tokens[slot] = max(self._tokens[slot], n_tokens)
        grew = False
        while self._n_alloc[slot] < need:
            # reservation accounting guarantees the free list is non-empty
            page = self._free.pop()
            self._table[slot][self._n_alloc[slot]] = page
            self._n_alloc[slot] += 1
            grew = True
        if grew:
            self._dirty = True
            self.stats.peak_pages = max(self.stats.peak_pages,
                                        self.allocated_total())
        return grew

    def slot_pages(self, slot: int) -> list[int]:
        """Physical pages currently mapped for the slot, in logical order."""
        return self._table[slot][: self._n_alloc[slot]]

    def release(self, slot: int) -> list[int]:
        """Finish/evict: return the slot's pages to the free list and drop
        its reservation. Returns the freed physical page ids."""
        freed = self.slot_pages(slot)
        self._free.extend(reversed(freed))
        self._table[slot] = [-1] * self.max_pages
        self._n_alloc[slot] = 0
        self._reserved[slot] = 0
        self._tokens[slot] = 0
        if freed:
            self._dirty = True
        return freed

    # -- device view ---------------------------------------------------------
    @property
    def scratch_page(self) -> int:
        """Physical index of the write-sink page (see module docstring)."""
        return self.n_pages

    def device_table(self):
        """(n_slots, max_pages) int32 jnp array; free entries -> scratch.
        Cached between calls until an alloc/release dirties it."""
        import jax.numpy as jnp
        import numpy as np

        if self._dirty or self._device_table is None:
            t = np.asarray(self._table, np.int32)
            t[t < 0] = self.scratch_page
            self._device_table = jnp.asarray(t)
            self._dirty = False
        return self._device_table

    # -- telemetry -----------------------------------------------------------
    def tick(self) -> None:
        """Sample occupancy/fragmentation once per decode step."""
        alloc = self.allocated_total()
        used = sum(self._tokens)
        cap = alloc * self.page_size
        self.stats.ticks += 1
        self.stats.page_steps += alloc
        if cap:
            self.stats.frag_weighted += 1.0 - used / cap

    def fragmentation(self) -> float:
        """Instantaneous internal fragmentation: the fraction of
        allocated page capacity not holding a live token."""
        cap = self.allocated_total() * self.page_size
        return (1.0 - sum(self._tokens) / cap) if cap else 0.0

    def summary(self) -> dict:
        return {
            "page_size": self.page_size,
            "n_pages": self.n_pages,
            "max_pages": self.max_pages,
            **self.stats.as_dict(),
        }

    # -- invariants (the fuzz suite's oracle) --------------------------------
    def check(self) -> None:
        """Assert every allocator invariant; raises AssertionError on the
        first violation. O(n_pages) — called after every event by the
        property tests, cheap enough to leave on in simulations."""
        live = [p for row, n in zip(self._table, self._n_alloc)
                for p in row[:n]]
        # no page is mapped by two live slots (aliasing) or twice
        assert len(live) == len(set(live)), "page aliased across slots"
        # free list holds no duplicates and no live page (double-free
        # would put a live page back on the list)
        free = set(self._free)
        assert len(free) == len(self._free), "free list duplicate"
        assert not (free & set(live)), "live page on the free list"
        # conservation: every page is exactly free or live (no leak)
        assert len(self._free) + len(live) == self.n_pages, "page leaked"
        for s in range(self.n_slots):
            row = self._table[s]
            n = self._n_alloc[s]
            assert all(0 <= p < self.n_pages for p in row[:n])
            assert all(p == -1 for p in row[n:]), "stale table entry"
            assert n <= self._reserved[s] <= self.max_pages
            assert self.pages_needed(self._tokens[s]) <= n
        # admission never over-promises the pool
        assert self.reserved_total() <= self.n_pages, "over-admitted"


__all__ = ["PagePool", "PoolStats", "pages_for"]
