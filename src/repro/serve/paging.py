"""Paged KV/state cache: a block-pool allocator for serve slots.

The contiguous serve cache gives every decode slot the worst-case time
footprint (``n_slots x cache_len`` tokens) even when most requests are
short — the same waste the paper removes from *weights* by packing
irregular sparsity into fixed-size blocks (CSB §4). This module applies
that regular-block philosophy to *activations*: the cache becomes a pool
of fixed-size token **pages** shared by all slots, and each slot maps
its logical positions onto physical pages through a dense page table.

Design points (all jit-friendliness driven):

* The page table is a dense ``(n_slots, max_pages)`` int32 array —
  passed straight into the jitted decode step, no ragged host structure
  crosses the trace boundary. Free entries hold ``-1`` on the host;
  :meth:`device_table` maps them to a dedicated **scratch page** (index
  ``n_pages``, one extra physical page the pools allocate beyond the
  allocator's range) so inactive slots write/gather somewhere harmless
  without any masking inside the step.
* **Reservation-based admission**: a request reserves its own worst case
  (``ceil((prompt + max_new) / page_size)`` pages) when admitted, and
  physical pages are allocated lazily as the position advances
  (:meth:`ensure`). Admission is bounded by *per-request* need, not the
  global max length — mixed-length traces pack more concurrent requests
  into the same token budget than contiguous slots can — and a slot can
  never stall mid-decode waiting for a page (no deadlock by
  construction).
* Pages are freed the moment a request finishes (:meth:`release`),
  mid-decode, and immediately reusable. Freed pages are NOT zeroed: the
  decode mask (``kpos <= pos``) plus the write-before-unmask order means
  a successor can never attend a predecessor's stale KV (see
  serve.scheduler's eviction notes; per-slot SSM/conv state, which has
  no mask, is still zeroed by the engine).

Prefix sharing (``prefix_cache=True``) layers a radix cache on top:

* Every physical page carries a **refcount**; a page is free iff its
  refcount is zero. A prefix **trie** keyed on per-page token tuples
  owns one reference to each registered prompt page, so prompt KV
  outlives the request that computed it.
* :meth:`try_reserve` walks the trie with the new prompt. Matched pages
  map straight into the slot (refcount bumped, zero prefill compute for
  the matched span); the reservation then counts only the *unshared*
  worst case. Matching is token-granular: after the whole-page walk, a
  child page whose tokens extend the remaining prompt is mapped
  partially, so divergence mid-page still shares the common span.
* The first write into a partially-shared page triggers **copy-on-write**
  (:meth:`cow_if_needed`): a private page is allocated from the pool (its
  cost was part of the reservation), the engine copies the page contents
  device-side, and the shared original keeps serving its other readers.
* When the free list runs dry, :meth:`_alloc_page` **reclaims** trie
  pages no live slot maps, LRU leaf first — retention is best-effort,
  reservations always win.

Host-side only — the device half (paged write/gather, page-granular
insert/copy) lives in ``models.layers`` / ``serve.scheduler``.
"""
from __future__ import annotations

import dataclasses

from repro.obs import metrics as obs_metrics, trace as obs_trace


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` (0 tokens still needs 0 pages)."""
    return -(-max(n_tokens, 0) // page_size)


@dataclasses.dataclass
class PoolStats:
    """Running occupancy/fragmentation telemetry (sampled via tick())."""

    peak_pages: int = 0
    ticks: int = 0
    page_steps: int = 0          # sum over ticks of allocated pages
    frag_weighted: float = 0.0   # sum over ticks of internal-frag fraction

    def as_dict(self) -> dict:
        return {
            "peak_pages": self.peak_pages,
            "mean_pages": round(self.page_steps / self.ticks, 2)
            if self.ticks else 0.0,
            "internal_fragmentation": round(
                self.frag_weighted / self.ticks, 4) if self.ticks else 0.0,
        }


@dataclasses.dataclass(frozen=True)
class SharedInfo:
    """Outcome of a prefix-cache admission (try_reserve).

    ``shared_tokens``— prompt tokens whose KV is already in the pool.
    ``shared_pages`` — physical pages mapped from the trie.
    ``suffix_start`` — first position prefill must compute. Capped at
                       ``prompt_len - 1`` so even a fully-matched prompt
                       re-prefills its last token (the engine needs its
                       logits to sample from).
    ``needs_cow``    — the suffix starts inside the last shared page, so
                       the engine must :meth:`PagePool.cow_if_needed` +
                       copy before any write.
    """

    shared_tokens: int = 0
    shared_pages: int = 0
    suffix_start: int = 0
    needs_cow: bool = False


class _TrieNode:
    """One page of a registered prompt: ``tokens`` (a page_size tuple)
    keyed under the parent, owning one refcount on ``page``."""

    __slots__ = ("tokens", "page", "children", "parent", "last_use")

    def __init__(self, tokens, page, parent):
        self.tokens = tokens
        self.page = page
        self.children = {}
        self.parent = parent
        self.last_use = 0


class PagePool:
    """Fixed-size token-page allocator behind the serve decode slots.

    ``n_pages``  — allocatable pool capacity (the scratch page the device
                   pools carry at index ``n_pages`` is NOT part of it).
    ``max_pages``— page-table width: the most pages one slot may ever
                   hold (``ceil(cache_len / page_size)``); bounds the
                   logical time extent the decode step gathers.
    ``prefix_cache`` — retain prompt pages in a refcounted radix trie and
                   share them across requests (see module docstring).
    """

    def __init__(self, page_size: int, n_pages: int, n_slots: int,
                 max_pages: int, prefix_cache: bool = False):
        if page_size < 1 or n_pages < 1 or n_slots < 1 or max_pages < 1:
            raise ValueError("page_size, n_pages, n_slots, max_pages "
                             "must all be >= 1")
        self.page_size = page_size
        self.n_pages = n_pages
        self.n_slots = n_slots
        self.max_pages = max_pages
        self.prefix_cache = prefix_cache
        # LIFO free list: recently freed pages are reused first (their
        # device-side contents are hottest in cache)
        self._free: list[int] = list(range(n_pages - 1, -1, -1))
        self._ref = [0] * n_pages         # refcount per physical page
        self._table = [[-1] * max_pages for _ in range(n_slots)]
        self._n_alloc = [0] * n_slots     # physical pages held per slot
        self._n_shared = [0] * n_slots    # leading trie-shared pages
        self._reserved = [0] * n_slots    # admission reservation per slot
        self._tokens = [0] * n_slots      # tokens ensure()d per slot
        self._write_floor = [0] * n_slots  # first position writes may touch
        self._info: list[SharedInfo | None] = [None] * n_slots
        self._root = _TrieNode(None, -1, None)
        self._clock = 0                   # LRU stamp for trie nodes
        self.cow_copies = 0
        self.trie_evictions = 0
        self.stats = PoolStats()
        self._dirty = True
        self._device_table = None

    # -- capacity / admission ------------------------------------------------
    def pages_needed(self, n_tokens: int) -> int:
        return pages_for(n_tokens, self.page_size)

    def reserved_total(self) -> int:
        return sum(self._reserved)

    def allocated_total(self) -> int:
        return self.n_pages - len(self._free)

    def _outstanding(self) -> int:
        """Pages already promised but not yet privately allocated."""
        return sum(
            max(self._reserved[s]
                - (self._n_alloc[s] - self._n_shared[s]), 0)
            for s in range(self.n_slots))

    def _evictable(self) -> int:
        """Trie pages reclaimable by repeated LRU leaf eviction: a node
        counts iff no slot maps its page AND its whole subtree counts."""
        def walk(node):
            cnt, whole = 0, True
            for ch in node.children.values():
                c, w = walk(ch)
                cnt += c
                whole = whole and w
            if node is self._root:
                return cnt, whole
            if whole and self._ref[node.page] == 1:
                return cnt + 1, True
            return cnt, False
        return walk(self._root)[0]

    def available(self) -> int:
        """Pages admission may still promise. Free pages plus reclaimable
        trie pages, minus what existing reservations may yet claim —
        reduces to ``n_pages - reserved_total()`` for trie-less pools."""
        return len(self._free) + self._evictable() - self._outstanding()

    def fits_ever(self, n_tokens: int) -> bool:
        """Could a request of this total length EVER be admitted?"""
        need = self.pages_needed(n_tokens)
        return need <= min(self.n_pages, self.max_pages)

    def can_admit(self, n_tokens: int) -> bool:
        need = self.pages_needed(n_tokens)
        return need <= self.max_pages and need <= self.available()

    # -- slot lifecycle ------------------------------------------------------
    def reserve(self, slot: int, n_tokens: int) -> None:
        """Admission: promise the slot its worst-case page count."""
        if self._reserved[slot]:
            raise RuntimeError(f"slot {slot} already holds a reservation")
        need = self.pages_needed(n_tokens)
        if not self.can_admit(n_tokens):
            raise RuntimeError(
                f"cannot reserve {need} pages for slot {slot}: "
                f"{self.available()} available, max_pages={self.max_pages}")
        self._reserved[slot] = need
        self._write_floor[slot] = 0
        self._info[slot] = None

    def try_reserve(self, slot: int, n_tokens: int,
                    tokens=None) -> SharedInfo | None:
        """Prefix-aware admission. Matches ``tokens`` (the prompt) against
        the trie, maps the shared span into the slot, and reserves only
        the unshared worst case (plus one page when divergence lands
        inside a shared page — the CoW copy). Atomic: on failure nothing
        is mapped or reserved and ``None`` is returned."""
        if self._reserved[slot] or self._n_alloc[slot]:
            raise RuntimeError(f"slot {slot} already holds a reservation")
        need_total = self.pages_needed(n_tokens)
        if need_total > self.max_pages:
            return None
        path, matched = ([], 0)
        if self.prefix_cache and tokens is not None:
            path, matched = self._match([int(t) for t in tokens])
        plen = len(tokens) if tokens is not None else 0
        while True:
            suffix_start = min(matched, plen - 1) if matched else 0
            if suffix_start <= 0:
                path, matched, suffix_start = [], 0, 0
            sp = len(path)
            cow = bool(sp) and suffix_start < sp * self.page_size
            need_priv = need_total - sp + (1 if cow else 0)
            # pin the path first: pinned nodes stop being evictable, and
            # the capacity check must see that
            for nd in path:
                self._ref[nd.page] += 1
            if need_priv <= len(self._free) + self._evictable() \
                    - self._outstanding():
                break
            for nd in path:
                self._ref[nd.page] -= 1
            if not path:
                return None
            # Sharing must never admit LESS than not sharing: a partial
            # match pays a CoW page while pinning the matched span out of
            # the evictable supply, so on a tight pool the shared plan
            # can exceed capacity where the unshared one fits (found by
            # the paging fuzz as a permanent FIFO stall). Retreat to the
            # whole-page boundary first (drops the CoW cost), then give
            # up sharing entirely before reporting failure.
            if cow:
                path = path[:-1]
                matched = len(path) * self.page_size
            else:
                path, matched = [], 0
        self._clock += 1
        for i, nd in enumerate(path):
            self._table[slot][i] = nd.page
            nd.last_use = self._clock
        self._n_alloc[slot] = sp
        self._n_shared[slot] = sp
        self._reserved[slot] = need_priv
        self._tokens[slot] = suffix_start
        self._write_floor[slot] = suffix_start
        info = SharedInfo(shared_tokens=matched, shared_pages=sp,
                          suffix_start=suffix_start, needs_cow=cow)
        self._info[slot] = info
        if sp:
            self._dirty = True
            obs_trace.instant("serve/pool/prefix_hit",
                              args={"slot": slot, "shared_pages": sp,
                                    "shared_tokens": matched})
            reg = obs_metrics.get()
            if reg is not None:
                reg.counter("serve/pool/prefix_hits").inc()
                reg.counter("serve/pool/shared_pages").inc(sp)
        return info

    def shared_info(self, slot: int) -> SharedInfo | None:
        """SharedInfo recorded by the slot's try_reserve (None after a
        plain reserve)."""
        return self._info[slot]

    def cow_if_needed(self, slot: int):
        """Copy-on-write the slot's last shared page if prefill/decode
        will write into it. Remaps the slot to a private page and returns
        ``(src, dst)`` for the engine's device-side page copy, or None
        when the write floor sits at/after the shared span already."""
        sp = self._n_shared[slot]
        if sp == 0 or self._write_floor[slot] >= sp * self.page_size:
            return None
        src = self._table[slot][sp - 1]
        dst = self._alloc_page()
        self._table[slot][sp - 1] = dst
        self._n_shared[slot] = sp - 1
        self._unref(src)
        self.cow_copies += 1
        self._dirty = True
        obs_trace.instant("serve/pool/cow",
                          args={"slot": slot, "src": src, "dst": dst})
        reg = obs_metrics.get()
        if reg is not None:
            reg.counter("serve/pool/cow_copies").inc()
        return (src, dst)

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow the slot's allocation to cover ``n_tokens`` positions.
        Returns True when the page table changed (new pages mapped)."""
        need = self.pages_needed(n_tokens)
        if need - self._n_shared[slot] > self._reserved[slot]:
            raise RuntimeError(
                f"slot {slot}: ensure({n_tokens}) needs "
                f"{need - self._n_shared[slot]} private pages but only "
                f"{self._reserved[slot]} are reserved")
        if n_tokens > self._write_floor[slot] \
                and self._write_floor[slot] \
                < self._n_shared[slot] * self.page_size:
            raise RuntimeError(
                f"slot {slot}: write into a shared page — call "
                "cow_if_needed() and copy the page first")
        self._tokens[slot] = max(self._tokens[slot], n_tokens)
        self._write_floor[slot] = max(self._write_floor[slot],
                                      self._tokens[slot])
        grew = False
        while self._n_alloc[slot] < need:
            page = self._alloc_page()
            self._table[slot][self._n_alloc[slot]] = page
            self._n_alloc[slot] += 1
            grew = True
        if grew:
            self._dirty = True
        return grew

    def truncate(self, slot: int, n_tokens: int) -> list[int]:
        """Roll the slot's logical length back to ``n_tokens``
        (speculative rollback past a rejected draft position). Whole tail
        pages beyond ``pages_needed(n_tokens)`` are unmapped; trie-held
        pages survive (prefix cache), purely private ones return to the
        free list. The boundary page — committed and stale KV mixed —
        stays mapped: stale entries sit at positions >= n_tokens, and the
        ``kpos <= pos`` decode mask never attends them, so no device-side
        zeroing is needed. ``write_floor`` is NOT lowered — those
        positions were legitimately written and the next verify step will
        overwrite them. Returns the pages actually freed."""
        if n_tokens > self._tokens[slot]:
            raise ValueError(
                f"slot {slot}: truncate({n_tokens}) beyond current "
                f"length {self._tokens[slot]}")
        if n_tokens < self._n_shared[slot] * self.page_size:
            raise ValueError(
                f"slot {slot}: truncate({n_tokens}) into the shared "
                f"prefix span ({self._n_shared[slot]} pages)")
        keep = self.pages_needed(n_tokens)
        freed = []
        while self._n_alloc[slot] > keep:
            self._n_alloc[slot] -= 1
            page = self._table[slot][self._n_alloc[slot]]
            self._table[slot][self._n_alloc[slot]] = -1
            if self._ref[page] == 1:
                freed.append(page)
            self._unref(page)
        self._tokens[slot] = n_tokens
        if freed:
            self._dirty = True
            obs_trace.instant("serve/pool/truncate",
                              args={"slot": slot, "n_tokens": n_tokens,
                                    "freed": len(freed)})
        return freed

    def register_prefix(self, slot: int, tokens) -> int:
        """Insert the slot's (fully prefilled) prompt pages into the trie
        so later requests can share them. Only whole pages register; the
        trie takes one reference per newly-registered page. Returns the
        number of pages added. No-op unless ``prefix_cache``."""
        if not self.prefix_cache:
            return 0
        toks = [int(t) for t in tokens]
        psz = self.page_size
        node = self._root
        self._clock += 1
        added = 0
        for i in range(len(toks) // psz):
            key = tuple(toks[i * psz:(i + 1) * psz])
            ch = node.children.get(key)
            if ch is None:
                page = self._table[slot][i]
                assert 0 <= page < self.n_pages, \
                    f"slot {slot}: registering unmapped page {i}"
                ch = _TrieNode(key, page, node)
                node.children[key] = ch
                self._ref[page] += 1
                added += 1
            ch.last_use = self._clock
            node = ch
        return added

    def slot_pages(self, slot: int) -> list[int]:
        """Physical pages currently mapped for the slot, in logical order."""
        return self._table[slot][: self._n_alloc[slot]]

    def slot_row(self, slot: int):
        """np int32 ``(max_pages,)`` physical row; unmapped -> scratch."""
        import numpy as np

        row = np.asarray(self._table[slot], np.int32)
        row[row < 0] = self.scratch_page
        return row

    def release(self, slot: int) -> list[int]:
        """Finish/evict: drop the slot's references and reservation. Pages
        the trie still holds survive (that is the prefix cache); the rest
        return to the free list. Returns the pages actually freed."""
        freed = []
        for p in self.slot_pages(slot):
            self._ref[p] -= 1
            if self._ref[p] == 0:
                freed.append(p)
        self._free.extend(reversed(freed))
        had = self._n_alloc[slot] > 0
        self._table[slot] = [-1] * self.max_pages
        self._n_alloc[slot] = 0
        self._n_shared[slot] = 0
        self._reserved[slot] = 0
        self._tokens[slot] = 0
        self._write_floor[slot] = 0
        self._info[slot] = None
        if had:
            self._dirty = True
        return freed

    def drop_prefix_cache(self) -> int:
        """Evict every trie page no live slot maps. Returns pages freed."""
        freed = 0
        while True:
            victim = self._lru_evictable_leaf()
            if victim is None:
                return freed
            self._evict_node(victim)
            freed += 1

    # -- page allocation / reclaim -------------------------------------------
    def _alloc_page(self) -> int:
        """Pop a free page, reclaiming from the trie when the list is dry
        (reservation accounting guarantees one exists)."""
        if not self._free:
            victim = self._lru_evictable_leaf()
            if victim is None:
                raise RuntimeError("page pool exhausted: reservation "
                                   "accounting violated (no reclaimable "
                                   "trie page)")
            self._evict_node(victim)
        page = self._free.pop()
        self._ref[page] = 1
        self.stats.peak_pages = max(self.stats.peak_pages,
                                    self.allocated_total())
        return page

    def _lru_evictable_leaf(self):
        best = None
        stack = list(self._root.children.values())
        while stack:
            nd = stack.pop()
            if nd.children:
                stack.extend(nd.children.values())
            elif self._ref[nd.page] == 1 and (
                    best is None or nd.last_use < best.last_use):
                best = nd
        return best

    def _evict_node(self, node) -> None:
        node.parent.children.pop(node.tokens)
        self._unref(node.page)
        self.trie_evictions += 1

    def _unref(self, page: int) -> None:
        self._ref[page] -= 1
        assert self._ref[page] >= 0, f"page {page} refcount underflow"
        if self._ref[page] == 0:
            self._free.append(page)

    def _match(self, toks):
        """Longest trie match: whole pages first, then a token-granular
        partial match against one child of the last matched node."""
        psz = self.page_size
        node = self._root
        path, matched = [], 0
        n_full = len(toks) // psz
        i = 0
        while i < n_full:
            ch = node.children.get(tuple(toks[i * psz:(i + 1) * psz]))
            if ch is None:
                break
            path.append(ch)
            node = ch
            matched += psz
            i += 1
        rem = toks[i * psz:]
        best, best_r = None, 0
        for ch in node.children.values():
            r = 0
            lim = min(len(rem), psz)
            while r < lim and ch.tokens[r] == rem[r]:
                r += 1
            if r > best_r:
                best, best_r = ch, r
        if best is not None:
            path.append(best)
            matched += best_r
        return path, matched

    # -- device view ---------------------------------------------------------
    @property
    def scratch_page(self) -> int:
        """Physical index of the write-sink page (see module docstring)."""
        return self.n_pages

    def device_table(self):
        """(n_slots, max_pages) int32 jnp array; free entries -> scratch.
        Cached between calls until an alloc/release dirties it."""
        import jax.numpy as jnp
        import numpy as np

        if self._dirty or self._device_table is None:
            t = np.asarray(self._table, np.int32)
            t[t < 0] = self.scratch_page
            self._device_table = jnp.asarray(t)
            self._dirty = False
        return self._device_table

    # -- telemetry -----------------------------------------------------------
    def tick(self) -> None:
        """Sample occupancy/fragmentation once per decode step. With
        :mod:`repro.obs.metrics` enabled, each sample also lands in the
        ``serve/pool/*`` gauge timelines — occupancy over the run, not
        just the end-of-run summary averages."""
        alloc = self.allocated_total()
        used = sum(self._tokens)
        cap = alloc * self.page_size
        self.stats.ticks += 1
        self.stats.page_steps += alloc
        frag = (1.0 - used / cap) if cap else 0.0
        if cap:
            self.stats.frag_weighted += frag
        reg = obs_metrics.get()
        if reg is not None:
            reg.gauge("serve/pool/pages").set(alloc)
            reg.gauge("serve/pool/free_pages").set(len(self._free))
            reg.gauge("serve/pool/fragmentation").set(round(frag, 4))
            if self.prefix_cache:
                reg.gauge("serve/pool/trie_pages").set(self.trie_pages())

    def fragmentation(self) -> float:
        """Instantaneous internal fragmentation: the fraction of
        allocated page capacity not holding a live token."""
        cap = self.allocated_total() * self.page_size
        return (1.0 - sum(self._tokens) / cap) if cap else 0.0

    def trie_pages(self) -> int:
        """Physical pages the trie currently holds a reference on."""
        cnt, stack = 0, list(self._root.children.values())
        while stack:
            nd = stack.pop()
            cnt += 1
            stack.extend(nd.children.values())
        return cnt

    def summary(self) -> dict:
        out = {
            "page_size": self.page_size,
            "n_pages": self.n_pages,
            "max_pages": self.max_pages,
            **self.stats.as_dict(),
        }
        if self.prefix_cache:
            out.update(prefix_cache=True, trie_pages=self.trie_pages(),
                       cow_copies=self.cow_copies,
                       trie_evictions=self.trie_evictions)
        return out

    # -- invariants (the fuzz suite's oracle) --------------------------------
    def check(self) -> None:
        """Assert every allocator invariant; raises AssertionError on the
        first violation. Called after every event by the property tests,
        cheap enough to leave on in simulations."""
        from collections import Counter

        live = [p for row, n in zip(self._table, self._n_alloc)
                for p in row[:n]]
        # walk the trie: structural sanity + the set of trie-owned pages
        trie = []
        stack = [(self._root, key, ch)
                 for key, ch in self._root.children.items()]
        while stack:
            parent, key, nd = stack.pop()
            assert nd.parent is parent and nd.tokens == key
            assert len(nd.tokens) == self.page_size, "partial page in trie"
            assert 0 <= nd.page < self.n_pages
            trie.append(nd.page)
            stack.extend((nd, k, c) for k, c in nd.children.items())
        tset = set(trie)
        assert len(trie) == len(tset), "page owned by two trie nodes"
        # refcount conservation: ref == slot mappings + trie ownership
        expect = Counter(live)
        expect.update(trie)
        for p in range(self.n_pages):
            assert self._ref[p] == expect.get(p, 0), \
                f"page {p}: refcount {self._ref[p]} != {expect.get(p, 0)}"
        # free list <=> refcount zero; no duplicates; no leak
        free = set(self._free)
        assert len(free) == len(self._free), "free list duplicate"
        assert all(self._ref[p] == 0 for p in free), \
            "referenced page on the free list"
        held = {p for p in range(self.n_pages) if self._ref[p] > 0}
        assert not (free & held)
        assert len(free) + len(held) == self.n_pages, "page leaked"
        # sharing happens ONLY through the trie (a CoW'd page must not
        # stay aliased): any page mapped by >1 slot is trie-owned
        for p, c in Counter(live).items():
            assert c == 1 or p in tset, "page aliased outside the trie"
        for s in range(self.n_slots):
            row = self._table[s]
            n = self._n_alloc[s]
            assert all(0 <= p < self.n_pages for p in row[:n])
            assert len(set(row[:n])) == n, "page mapped twice in one slot"
            assert all(p == -1 for p in row[n:]), "stale table entry"
            assert 0 <= self._n_shared[s] <= n
            assert all(p in tset for p in row[:self._n_shared[s]]), \
                "shared-mapped page lost its trie node"
            # write isolation: a slot's writes span [suffix_start,
            # write_floor). Once that span is non-empty, every shared page
            # must sit strictly below it (CoW must have run first).
            info = self._info[s]
            floor0 = info.suffix_start if info is not None else 0
            if self._write_floor[s] > floor0:
                assert self._n_shared[s] * self.page_size <= floor0, \
                    f"slot {s}: write into shared pages without CoW"
            priv = n - self._n_shared[s]
            assert priv <= self._reserved[s]
            assert self._reserved[s] <= self.max_pages
            assert self.pages_needed(self._tokens[s]) <= n
        # admission never over-promises: every outstanding private claim
        # is coverable by free + reclaimable pages (no deadlock)
        assert self._outstanding() <= len(self._free) + self._evictable(), \
            "over-admitted"


__all__ = ["PagePool", "PoolStats", "SharedInfo", "pages_for"]
