"""repro.serve — batched + continuous-batching inference loops.

``engine`` owns the device loops (fixed-batch ``generate``, slot-based
``serve_continuous`` — contiguous or paged cache, pow2 prompt-bucketed
prefill, copy-on-write prefix sharing — and frame-by-frame
``rnn_serve_frames``), all of which run sharded under the ``dist`` rules
when a mesh is supplied; ``scheduler`` owns request admission and
slot/page-granular cache reuse; ``paging`` owns the fixed-size
token-page pool (free list + dense page table + refcounted prefix trie)
behind the paged cache. See docs/serving.md for the end-to-end tour.
"""
from .engine import (
    ServeConfig,
    ServeResult,
    bucket_len,
    generate,
    rnn_serve_frames,
    serve_continuous,
    shard_cell_params,
)
from .paging import PagePool, SharedInfo, pages_for
from .scheduler import (
    Request,
    SlotScheduler,
    cache_len_of,
    copy_page_cache,
    evict_slot,
    evict_slot_state,
    fit_cache_len,
    grow_cache,
    insert_paged_cache,
    insert_paged_span,
    insert_slot_cache,
    simulate_admission,
)

__all__ = [
    "ServeConfig", "ServeResult", "bucket_len", "generate",
    "rnn_serve_frames", "serve_continuous", "shard_cell_params",
    "PagePool", "SharedInfo", "pages_for",
    "Request", "SlotScheduler", "cache_len_of", "copy_page_cache",
    "evict_slot", "evict_slot_state", "fit_cache_len", "grow_cache",
    "insert_paged_cache", "insert_paged_span", "insert_slot_cache",
    "simulate_admission",
]
