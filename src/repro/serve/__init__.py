"""repro.serve — batched inference loops."""
from .engine import ServeConfig, generate, rnn_serve_frames

__all__ = ["ServeConfig", "generate", "rnn_serve_frames"]
