"""repro.serve — batched + continuous-batching inference loops.

``engine`` owns the device loops (fixed-batch ``generate``, slot-based
``serve_continuous``, frame-by-frame ``rnn_serve_frames``), all of which
run sharded under the ``dist`` rules when a mesh is supplied;
``scheduler`` owns request admission and slot-granular cache reuse.
"""
from .engine import (
    ServeConfig,
    ServeResult,
    generate,
    rnn_serve_frames,
    serve_continuous,
    shard_cell_params,
)
from .scheduler import (
    Request,
    SlotScheduler,
    cache_len_of,
    evict_slot,
    grow_cache,
    insert_slot_cache,
    simulate_admission,
)

__all__ = [
    "ServeConfig", "ServeResult", "generate", "rnn_serve_frames",
    "serve_continuous", "shard_cell_params",
    "Request", "SlotScheduler", "cache_len_of", "evict_slot",
    "grow_cache", "insert_slot_cache", "simulate_admission",
]
