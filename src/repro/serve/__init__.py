"""repro.serve — batched + continuous-batching inference loops.

``config`` owns the unified :class:`EngineConfig` every entry point
consumes; ``engine`` owns
the device loops (fixed-batch ``generate``, slot-based
``serve_continuous`` — contiguous or paged cache, pow2 prompt-bucketed
prefill, copy-on-write prefix sharing — and frame-by-frame
``rnn_serve_frames``), all of which run sharded under the ``dist``
rules when a mesh is supplied; ``disagg`` splits the engine into a
prefill tier and a fixed-slot decode tier joined by explicit
:class:`PageHandoff` remaps; ``speculative`` drafts with a CSB-pruned
copy of the target and verifies ``spec_k``-token runs in one
multi-position decode step; ``router`` places a request trace over N
engine replicas (load-aware via ``simulate_admission``) and simulates
fleet-wide SLO attainment; ``scheduler`` owns request admission and
slot/page-granular cache reuse; ``paging`` owns the fixed-size
token-page pool (free list + dense page table + refcounted prefix
trie) behind the paged cache. See docs/serving.md for the end-to-end
tour.
"""
from .config import EngineConfig
from .disagg import (
    DecodeTier,
    PageHandoff,
    PrefillTier,
    serve_disaggregated,
)
from .engine import (
    ServeResult,
    bucket_len,
    generate,
    rnn_serve_frames,
    serve_continuous,
    shard_cell_params,
)
from .paging import PagePool, SharedInfo, pages_for
from .router import (
    POLICIES,
    Router,
    RouterResult,
    make_arrival_trace,
    route,
    simulate_replicas,
)
from .speculative import (
    derive_draft_params,
    generate_speculative,
    serve_continuous_speculative,
)
from .scheduler import (
    Request,
    SlotScheduler,
    cache_len_of,
    copy_page_cache,
    evict_slot,
    evict_slot_state,
    fit_cache_len,
    grow_cache,
    insert_paged_cache,
    insert_paged_span,
    insert_slot_cache,
    simulate_admission,
)

__all__ = [
    "EngineConfig", "ServeResult", "bucket_len",
    "generate", "rnn_serve_frames", "serve_continuous",
    "shard_cell_params",
    "DecodeTier", "PageHandoff", "PrefillTier", "serve_disaggregated",
    "POLICIES", "Router", "RouterResult", "make_arrival_trace", "route",
    "simulate_replicas",
    "PagePool", "SharedInfo", "pages_for",
    "derive_draft_params", "generate_speculative",
    "serve_continuous_speculative",
    "Request", "SlotScheduler", "cache_len_of", "copy_page_cache",
    "evict_slot", "evict_slot_state", "fit_cache_len", "grow_cache",
    "insert_paged_cache", "insert_paged_span", "insert_slot_cache",
    "simulate_admission",
]
