"""One serving configuration object for every engine entry point.

``serve_continuous`` grew ten loose keyword knobs (slots, cache length,
paging, bucketing, prefix sharing, the Pallas decode kernel, ...) while
``generate`` took a separate three-field ``ServeConfig`` — the same
engine, two half-configs. :class:`EngineConfig` folds all of it into a
single validated frozen dataclass consumed by ``generate``,
``serve_continuous``, ``rnn_serve_frames``, ``serve_disaggregated`` and
the multi-replica :class:`repro.serve.router.Router`.

Cross-field constraints live in ``__post_init__`` so an invalid
combination fails at construction, not three layers deep in the engine:
``use_kernel``/``prefix_cache``/``pool_pages`` all require ``paged``
(the kernel walks the page table; the trie shares pages; the pool IS
the paged budget).

Deprecation (one release): the old loose kwargs still work through
:func:`resolve_config` — they are mapped onto an ``EngineConfig`` and a
``DeprecationWarning`` is emitted. ``ServeConfig`` remains importable
as a warning subclass of ``EngineConfig`` so old call sites keep
running unchanged. See docs/serving.md for the migration table.
"""
from __future__ import annotations

import dataclasses
import warnings

__all__ = ["EngineConfig", "ServeConfig", "resolve_config"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Unified serving configuration (see module docstring).

    Generation:
      ``max_new_tokens`` — tokens to generate per request/batch row.
      ``temperature``    — 0 => greedy (the parity-testable path).
      ``cache_len``      — decode cache time capacity; default fits
                           prompt + new tokens.

    Continuous batching:
      ``n_slots``        — fixed decode batch width.

    Paged cache (``paged=True``):
      ``page_size``      — tokens per physical page.
      ``pool_pages``     — pool capacity in pages (default: the full
                           contiguous footprint ``n_slots * max_pages``).
      ``prefix_cache``   — refcounted radix-trie prompt sharing + CoW.
      ``use_kernel``     — Pallas paged-attention decode kernel.

    Prefill:
      ``bucket_prompts`` — pow2 prompt buckets (None: on when paged,
                           auto-off for SSD/hybrid mixers).

    Frame serving (``rnn_serve_frames``):
      ``frame_warmup``         — compile/warmup steps before timing.
      ``collect_frame_times``  — per-frame blocking latency pass.
    """

    # generation
    max_new_tokens: int = 32
    temperature: float = 0.0
    cache_len: int | None = None
    # continuous batching
    n_slots: int = 4
    # paged cache
    paged: bool = False
    page_size: int = 16
    pool_pages: int | None = None
    prefix_cache: bool = False
    use_kernel: bool = False
    # prefill
    bucket_prompts: bool | None = None
    # frame serving
    frame_warmup: int = 2
    collect_frame_times: bool = False

    def __post_init__(self):
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.temperature < 0.0:
            raise ValueError("temperature must be >= 0")
        if self.cache_len is not None and self.cache_len < 1:
            raise ValueError("cache_len must be >= 1 (or None)")
        if self.n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if self.page_size < 1:
            raise ValueError("page_size must be >= 1")
        if self.frame_warmup < 0:
            raise ValueError("frame_warmup must be >= 0")
        if not self.paged:
            # every paged-only knob must fail loudly instead of being
            # silently ignored by the contiguous engine
            for knob in ("use_kernel", "prefix_cache"):
                if getattr(self, knob):
                    raise ValueError(f"{knob}=True requires paged=True")
            if self.pool_pages is not None:
                raise ValueError("pool_pages requires paged=True")
        if self.pool_pages is not None and self.pool_pages < 1:
            raise ValueError("pool_pages must be >= 1 (or None)")

    def replace(self, **updates) -> "EngineConfig":
        """A modified copy (re-validated); always a base EngineConfig."""
        cfg = _as_base(self)
        return dataclasses.replace(cfg, **updates)


def _as_base(config: EngineConfig) -> EngineConfig:
    """Normalize subclasses (the ServeConfig shim) to plain EngineConfig
    so ``dataclasses.replace`` never re-enters a shim ``__init__``."""
    if type(config) is EngineConfig:
        return config
    return EngineConfig(**{f.name: getattr(config, f.name)
                           for f in dataclasses.fields(EngineConfig)})


class ServeConfig(EngineConfig):
    """Deprecated: the old three-field generate config. Constructs an
    :class:`EngineConfig` and warns; removed next release."""

    def __init__(self, max_new_tokens: int = 32, temperature: float = 0.0,
                 cache_len: int | None = None):
        warnings.warn(
            "ServeConfig is deprecated; use repro.serve.EngineConfig "
            "(same fields plus the serve/paging/kernel knobs)",
            DeprecationWarning, stacklevel=2)
        super().__init__(max_new_tokens=max_new_tokens,
                         temperature=temperature, cache_len=cache_len)


# the loose serve_continuous kwargs the one-release shim still accepts
LEGACY_SERVE_KWARGS = frozenset({
    "n_slots", "temperature", "cache_len", "paged", "page_size",
    "pool_pages", "bucket_prompts", "prefix_cache", "use_kernel",
    "max_new_tokens",
})


def resolve_config(config: EngineConfig | None, legacy: dict, *,
                   caller: str) -> EngineConfig:
    """Fold deprecated loose kwargs onto an :class:`EngineConfig`.

    ``legacy`` is the caller's ``**kwargs`` capture. Unknown names raise
    ``TypeError`` (exactly like a real unexpected keyword); known ones
    override ``config`` (or the defaults) and emit a single
    ``DeprecationWarning`` naming the replacement field(s). The merged
    config re-runs ``__post_init__``, so an invalid legacy combination
    (``prefix_cache=True`` without ``paged=True``) still raises
    ``ValueError`` as the engine always did.
    """
    if legacy:
        unknown = sorted(set(legacy) - LEGACY_SERVE_KWARGS)
        if unknown:
            raise TypeError(
                f"{caller}() got unexpected keyword argument(s) {unknown}")
        named = ", ".join(f"{k}=..." for k in sorted(legacy))
        warnings.warn(
            f"passing {sorted(legacy)} to {caller}() is deprecated; pass "
            f"config=EngineConfig({named}) instead (one-release shim)",
            DeprecationWarning, stacklevel=3)
        base = _as_base(config) if config is not None else EngineConfig()
        return dataclasses.replace(base, **legacy)
    if config is None:
        return EngineConfig()
    return _as_base(config)
