"""One serving configuration object for every engine entry point.

``serve_continuous`` grew ten loose keyword knobs (slots, cache length,
paging, bucketing, prefix sharing, the Pallas decode kernel, ...) while
``generate`` took a separate three-field ``ServeConfig`` — the same
engine, two half-configs. :class:`EngineConfig` folds all of it into a
single validated frozen dataclass consumed by ``generate``,
``serve_continuous``, ``rnn_serve_frames``, ``serve_disaggregated`` and
the multi-replica :class:`repro.serve.router.Router`.

Cross-field constraints live in ``__post_init__`` so an invalid
combination fails at construction, not three layers deep in the engine:
``use_kernel``/``prefix_cache``/``pool_pages`` all require ``paged``
(the kernel walks the page table; the trie shares pages; the pool IS
the paged budget), and the speculative knobs require ``speculative``.

The one-release loose-kwargs shim (``ServeConfig`` + DeprecationWarning
mapping in ``resolve_config``) shipped in the previous release and is
now gone: loose kwargs raise ``TypeError`` from the real signature.
See docs/serving.md for the migration table.
"""
from __future__ import annotations

import dataclasses

__all__ = ["EngineConfig", "resolve_config"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Unified serving configuration (see module docstring).

    Generation:
      ``max_new_tokens`` — tokens to generate per request/batch row.
      ``temperature``    — 0 => greedy (the parity-testable path).
      ``cache_len``      — decode cache time capacity; default fits
                           prompt + new tokens.

    Continuous batching:
      ``n_slots``        — fixed decode batch width.

    Paged cache (``paged=True``):
      ``page_size``      — tokens per physical page.
      ``pool_pages``     — pool capacity in pages (default: the full
                           contiguous footprint ``n_slots * max_pages``).
      ``prefix_cache``   — refcounted radix-trie prompt sharing + CoW.
      ``use_kernel``     — Pallas paged-attention decode kernel.

    Speculative decoding (``speculative=True``):
      ``spec_k``           — draft tokens proposed per verify round.
      ``draft_prune_rate`` — CSB pruning rate for the self-drafted
                             model (0.0 => draft == target, the parity
                             configuration).

    Prefill:
      ``bucket_prompts`` — pow2 prompt buckets (None: on when paged,
                           auto-off for SSD/hybrid mixers).

    Frame serving (``rnn_serve_frames``):
      ``frame_warmup``         — compile/warmup steps before timing.
      ``collect_frame_times``  — per-frame blocking latency pass.
    """

    # generation
    max_new_tokens: int = 32
    temperature: float = 0.0
    cache_len: int | None = None
    # continuous batching
    n_slots: int = 4
    # paged cache
    paged: bool = False
    page_size: int = 16
    pool_pages: int | None = None
    prefix_cache: bool = False
    use_kernel: bool = False
    # speculative decoding
    speculative: bool = False
    spec_k: int = 4
    draft_prune_rate: float = 0.5
    # prefill
    bucket_prompts: bool | None = None
    # frame serving
    frame_warmup: int = 2
    collect_frame_times: bool = False

    def __post_init__(self):
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.temperature < 0.0:
            raise ValueError("temperature must be >= 0")
        if self.cache_len is not None and self.cache_len < 1:
            raise ValueError("cache_len must be >= 1 (or None)")
        if self.n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if self.page_size < 1:
            raise ValueError("page_size must be >= 1")
        if self.frame_warmup < 0:
            raise ValueError("frame_warmup must be >= 0")
        if not self.paged:
            # every paged-only knob must fail loudly instead of being
            # silently ignored by the contiguous engine
            for knob in ("use_kernel", "prefix_cache"):
                if getattr(self, knob):
                    raise ValueError(f"{knob}=True requires paged=True")
            if self.pool_pages is not None:
                raise ValueError("pool_pages requires paged=True")
        if self.pool_pages is not None and self.pool_pages < 1:
            raise ValueError("pool_pages must be >= 1 (or None)")
        if self.spec_k < 1:
            raise ValueError("spec_k must be >= 1")
        if not 0.0 <= self.draft_prune_rate < 1.0:
            raise ValueError("draft_prune_rate must be in [0, 1)")
        if self.speculative and self.prefix_cache:
            raise ValueError(
                "speculative=True does not support prefix_cache=True "
                "(the draft has no shared-page partial prefill)")

    def replace(self, **updates) -> "EngineConfig":
        """A modified copy (re-validated)."""
        return dataclasses.replace(self, **updates)


def resolve_config(config: EngineConfig | None, *,
                   caller: str) -> EngineConfig:
    """Normalize the ``config=`` argument: ``None`` means defaults, and
    anything that is not an :class:`EngineConfig` raises ``TypeError``
    naming the caller (the loose-kwargs shim that used to live here was
    removed after its one-release deprecation window)."""
    if config is None:
        return EngineConfig()
    if not isinstance(config, EngineConfig):
        raise TypeError(
            f"{caller}() expects config=EngineConfig(...), got "
            f"{type(config).__name__}")
    return config
