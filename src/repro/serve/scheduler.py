"""Continuous-batching scheduler: request slots, admission, per-slot cache.

The serve engine holds a fixed batch of ``n_slots`` decode *slots*
(fixed shapes keep the decode step jitted once); requests flow through
slots continuously — a finished request frees its slot mid-decode and
the next queued prompt is prefilled straight into it, the way the
paper's CSB engine keeps every PEGroup busy by re-balancing block work
(§5.2) — here the balancing unit is a whole request.

Split of responsibilities:

* :class:`SlotScheduler` — pure host-side bookkeeping: admission queue,
  per-slot position/remaining-token state, occupancy accounting. It
  never touches a device array, so the same object is driven by the
  real engine (``serve.engine.serve_continuous``) and by the modelless
  :func:`simulate_admission` replay that launch/dryrun.py records.
* :func:`insert_slot_cache` / :func:`evict_slot` — the device half:
  slot-granular KV/state reuse. A freshly prefilled request cache
  (batch 1, its own prompt length) is written into slot ``i`` of the
  batch cache with one fused ``dynamic_update_slice`` per leaf; a
  finished slot is zeroed so no request's KV/SSM state ever leaks into
  its successor.
* :func:`cache_len_of` / :func:`grow_cache` — time-dim introspection /
  growth shared by the fixed-batch and continuous paths (moved here
  from serve.engine; engine re-exports them).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as obs_metrics, trace as obs_trace

PyTree = Any

# cache leaves carrying a (L, B, T, ...) time dimension at axis 2
_TIME_KEYS = ("k", "v", "c_kv", "k_rope")


# ---------------------------------------------------------------------------
# cache time-dim helpers
# ---------------------------------------------------------------------------

def cache_len_of(cache: PyTree) -> int:
    """Time capacity T of a decode cache (0 for empty / pure-state
    caches such as SSD, whose conv/ssm leaves carry no time dim)."""
    for path, leaf in jax.tree_util.tree_leaves_with_path(cache):
        keys = [getattr(k, "key", "") for k in path]
        if keys and keys[-1] in ("k", "v", "c_kv"):
            return leaf.shape[2]   # (L, B, T, ...)
    return 0


def grow_cache(cache: PyTree, extra: int) -> PyTree:
    """Pad every time-keyed leaf by ``extra`` along its time dim.

    No-op for ``extra <= 0``, for empty caches, and for leaves without a
    time dim (conv/ssm state) — so ragged caches (hybrid: attn leaves
    carry T, ssd leaves don't) grow only where growth means anything.
    """
    if extra <= 0:
        return cache

    def grow(path, leaf):
        keys = [getattr(k, "key", "") for k in path]
        if keys and keys[-1] in _TIME_KEYS and leaf.ndim >= 3:
            pad = [(0, 0)] * leaf.ndim
            pad[2] = (0, extra)
            return jnp.pad(leaf, pad)
        return leaf

    return jax.tree_util.tree_map_with_path(grow, cache)


def fit_cache_len(cache: PyTree, t: int) -> PyTree:
    """Grow or truncate every time-keyed leaf to exactly ``t`` time
    positions (the paged insert needs a whole number of pages)."""
    cur = cache_len_of(cache)
    if cur < t:
        return grow_cache(cache, t - cur)
    if cur == t:
        return cache

    def cut(path, leaf):
        keys = [getattr(k, "key", "") for k in path]
        if keys and keys[-1] in _TIME_KEYS and leaf.ndim >= 3:
            return leaf[:, :, :t]
        return leaf

    return jax.tree_util.tree_map_with_path(cut, cache)


# ---------------------------------------------------------------------------
# slot-granular cache ops (device side)
# ---------------------------------------------------------------------------

@partial(jax.jit, donate_argnums=(0,))
def _insert(batch_cache: PyTree, slot_cache: PyTree, slot) -> PyTree:
    def one(b, u):
        starts = (0, slot) + (0,) * (b.ndim - 2)
        return jax.lax.dynamic_update_slice(b, u.astype(b.dtype), starts)

    return jax.tree.map(one, batch_cache, slot_cache)


def insert_slot_cache(batch_cache: PyTree, slot_cache: PyTree,
                      slot: int) -> PyTree:
    """Write a prefilled single-request cache into batch slot ``slot``.

    ``slot_cache`` leaves are (L, 1, T_req, ...) with T_req <= the batch
    cache's capacity; time positions beyond T_req keep whatever the
    batch cache held — harmless, because decode masks attention to
    ``kpos <= pos`` and overwrites position ``pos`` before first use.
    """
    return _insert(batch_cache, slot_cache, jnp.asarray(slot, jnp.int32))


@partial(jax.jit, donate_argnums=(0,))
def _evict(batch_cache: PyTree, slot) -> PyTree:
    def one(b):
        upd = jnp.zeros((b.shape[0], 1) + b.shape[2:], b.dtype)
        starts = (0, slot) + (0,) * (b.ndim - 2)
        return jax.lax.dynamic_update_slice(b, upd, starts)

    return jax.tree.map(one, batch_cache)


def evict_slot(batch_cache: PyTree, slot: int) -> PyTree:
    """Zero slot ``slot`` across every cache leaf. Attention masking
    alone already prevents a successor from *attending* stale KV; the
    zeroing additionally clears carried state (SSM/conv) so nothing of
    a finished request survives into the slot's next tenant."""
    return _evict(batch_cache, jnp.asarray(slot, jnp.int32))


# ---------------------------------------------------------------------------
# paged-cache slot ops (device side; serve.paging owns the page table)
# ---------------------------------------------------------------------------

@partial(jax.jit, donate_argnums=(0,))
def _insert_paged(batch_cache: PyTree, slot_cache: PyTree, phys, slot):
    def one(path, b, u):
        keys = [getattr(k, "key", "") for k in path]
        if keys and keys[-1] in _TIME_KEYS and u.ndim >= 3:
            # b: (L, N_pool, P, ...) pool; u: (L, 1, n*P, ...) request
            l, psz = b.shape[0], b.shape[2]
            n = phys.shape[0]
            pages = u[:, 0].reshape((l, n, psz) + u.shape[3:])
            return b.at[:, phys].set(pages.astype(b.dtype))
        # state leaf: per-slot layout, same write as the contiguous path
        starts = (0, slot) + (0,) * (b.ndim - 2)
        return jax.lax.dynamic_update_slice(b, u.astype(b.dtype), starts)

    return jax.tree_util.tree_map_with_path(one, batch_cache, slot_cache)


def insert_paged_cache(batch_cache: PyTree, slot_cache: PyTree,
                       phys_pages, slot: int) -> PyTree:
    """Write a prefilled single-request cache into the paged batch cache.

    Time-keyed leaves of ``slot_cache`` must span exactly
    ``len(phys_pages) * page_size`` positions (``fit_cache_len``); each
    logical page i lands in physical page ``phys_pages[i]`` across all
    layers at once. Pages are fully overwritten, so a recycled page
    carries nothing of its previous tenant below the decode position
    (beyond it, the ``kpos <= pos`` mask applies — see serve.paging).
    State leaves write into batch slot ``slot`` as in
    :func:`insert_slot_cache`. Retraces once per distinct page count;
    the engine pads ``phys_pages`` to a pow2 count with the pool's
    scratch page so the variants stay O(log max_pages).
    """
    return _insert_paged(batch_cache, slot_cache,
                         jnp.asarray(phys_pages, jnp.int32),
                         jnp.asarray(slot, jnp.int32))


@partial(jax.jit, donate_argnums=(0,))
def _insert_span(batch_cache: PyTree, suffix_cache: PyTree, row, start,
                 length, slot):
    def one(path, b, u):
        keys = [getattr(k, "key", "") for k in path]
        if keys and keys[-1] in _TIME_KEYS and u.ndim >= 3:
            # b: (L, N_pool, P, ...) pool; u: (L, 1, S_pad, ...) suffix
            psz = b.shape[2]
            scratch = b.shape[1] - 1
            idx = jnp.arange(u.shape[2])
            posv = start + idx
            logical = jnp.clip(posv // psz, 0, row.shape[0] - 1)
            page = jnp.where(idx < length, row[logical], scratch)
            return b.at[:, page, posv % psz].set(u[:, 0].astype(b.dtype))
        starts = (0, slot) + (0,) * (b.ndim - 2)
        return jax.lax.dynamic_update_slice(b, u.astype(b.dtype), starts)

    return jax.tree_util.tree_map_with_path(one, batch_cache, suffix_cache)


def insert_paged_span(batch_cache: PyTree, suffix_cache: PyTree, row,
                      start: int, length: int, slot: int) -> PyTree:
    """Scatter a partially-prefilled suffix cache into the paged pool.

    The prefix-cache admission path: ``suffix_cache`` time leaves span
    positions ``[start, start + length)`` of the request (start = the
    divergence point; entries past ``length`` are bucket padding). Each
    position lands at ``(row[pos // page_size], pos % page_size)`` —
    token-granular, so a CoW'd divergence page keeps its shared head and
    gains the suffix tail. Padding positions route to the pool's scratch
    page (``row`` rides scratch-filled from ``PagePool.slot_row``, and
    the row width pins the compiled variant count to the table width).
    State leaves write into batch slot ``slot`` whole, as in
    :func:`insert_slot_cache`.
    """
    return _insert_span(batch_cache, suffix_cache,
                        jnp.asarray(row, jnp.int32),
                        jnp.asarray(start, jnp.int32),
                        jnp.asarray(length, jnp.int32),
                        jnp.asarray(slot, jnp.int32))


@partial(jax.jit, donate_argnums=(0,))
def _copy_page(batch_cache: PyTree, src, dst):
    def one(path, b):
        keys = [getattr(k, "key", "") for k in path]
        if keys and keys[-1] in _TIME_KEYS:
            return b.at[:, dst].set(b[:, src])
        return b

    return jax.tree_util.tree_map_with_path(one, batch_cache)


def copy_page_cache(batch_cache: PyTree, src: int, dst: int) -> PyTree:
    """Copy-on-write support: duplicate physical page ``src`` into
    ``dst`` across every pool (time) leaf. The engine calls this with
    the pair ``PagePool.cow_if_needed`` returns, BEFORE the first write
    into the slot's divergence page, so the shared original keeps
    serving its other readers untouched."""
    return _copy_page(batch_cache, jnp.asarray(src, jnp.int32),
                      jnp.asarray(dst, jnp.int32))


@partial(jax.jit, donate_argnums=(0,))
def _evict_state(batch_cache: PyTree, slot):
    def one(path, b):
        keys = [getattr(k, "key", "") for k in path]
        if keys and keys[-1] in _TIME_KEYS:
            return b            # pool leaf: pages freed by the allocator
        upd = jnp.zeros((b.shape[0], 1) + b.shape[2:], b.dtype)
        starts = (0, slot) + (0,) * (b.ndim - 2)
        return jax.lax.dynamic_update_slice(b, upd, starts)

    return jax.tree_util.tree_map_with_path(one, batch_cache)


def evict_slot_state(batch_cache: PyTree, slot: int) -> PyTree:
    """Paged eviction: zero only the per-slot state leaves (SSM/conv —
    they carry no position mask, so they MUST be cleared). The KV pages
    themselves just return to the allocator's free list; the decode
    mask plus page-granular overwrite keeps them unleakable without a
    device-side zero (serve.paging module docstring)."""
    return _evict_state(batch_cache, jnp.asarray(slot, jnp.int32))


# ---------------------------------------------------------------------------
# host-side scheduling
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request.

    ``arrival`` is measured in decode steps: the request may not be
    admitted before the engine's clock reaches it (the mixed-length
    prompts-arriving-over-time workload).

    ``deadline_us`` is optional SLO metadata (None: no deadline): the
    wall-time budget from arrival to last token. The scheduler only
    records it — :meth:`SlotScheduler.slo_report` (and through it
    :func:`simulate_admission` / the serve router) converts the step
    clock into microseconds under a per-step cost model and reports
    attainment against it.
    """

    rid: int
    tokens: Any                       # (S,) or (S, K) prompt token ids
    max_new_tokens: int = 32
    arrival: int = 0
    deadline_us: float | None = None

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.tokens).shape[0])


@dataclasses.dataclass
class _Slot:
    rid: int
    pos: int                          # next cache write position
    remaining: int
    generated: list = dataclasses.field(default_factory=list)


class SlotScheduler:
    """Admission + slot bookkeeping. Drives nothing itself — the engine
    (or :func:`simulate_admission`) owns the loop and tells the
    scheduler what happened.

    With a :class:`repro.serve.paging.PagePool` attached, admission is
    **by free pages, not free slots**: a free slot only takes a request
    when the pool can reserve its worst-case page count, and a finished
    request's pages return to the pool inside :meth:`_finish` (so
    scheduler and allocator can never disagree about liveness — the
    fuzz suite leans on this). The engine still owns physical page
    growth (``pool.ensure``) because only it knows when device writes
    happen.
    """

    def __init__(self, n_slots: int, pool=None):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self.pool = pool
        self.now = 0                  # decode-step clock
        self._pending: list[Request] = []
        self._slots: list[_Slot | None] = [None] * n_slots
        self.results: dict[int, list[int]] = {}
        self.prefills = 0
        self.decode_steps = 0
        self.idle_steps = 0
        self.active_slot_steps = 0
        self.peak_active = 0
        self.page_stalls = 0          # admissions deferred for pages
        self.prefix_hits = 0          # admissions that matched the trie
        self.shared_pages = 0         # pages mapped shared across them
        # per-request lifecycle in step time: arrival/admit/finish steps
        # + the request's deadline — the raw material of slo_report()
        self.req_log: dict[int, dict] = {}

    # -- submission / admission --------------------------------------------
    def submit(self, req: Request) -> None:
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.pool is not None and not self.pool.fits_ever(
                req.prompt_len + req.max_new_tokens):
            raise ValueError(
                f"request {req.rid} needs "
                f"{self.pool.pages_needed(req.prompt_len + req.max_new_tokens)}"
                f" pages and can never fit the pool "
                f"({self.pool.n_pages} pages, {self.pool.max_pages}/slot)")
        self._pending.append(req)
        self._pending.sort(key=lambda r: (r.arrival, r.rid))
        self.req_log[req.rid] = {"arrival": req.arrival,
                                 "deadline_us": req.deadline_us}

    def has_work(self) -> bool:
        return bool(self._pending) or any(
            s is not None for s in self._slots)

    def admit(self, limit: int | None = None) -> list[tuple[int, Request]]:
        """Fill free slots with arrived requests (FIFO by arrival).
        The engine must prefill each returned request and then call
        :meth:`started` with the token its prefill produced.

        Paged: the FIFO head must fit the pool's available pages or
        admission stops for this step (strict FIFO — no later request
        jumps a starved head, so admission order stays deterministic and
        starvation-free; pages drain back as running requests finish).

        ``limit`` caps the admissions per call — the prefix-cache engine
        admits one at a time so each prompt is registered before the
        next admission's trie match runs (same-step sharing)."""
        out = []
        for i in range(self.n_slots):
            if limit is not None and len(out) >= limit:
                break
            if self._slots[i] is not None:
                continue
            req = next((r for r in self._pending if r.arrival <= self.now),
                       None)
            if req is None:
                break
            total = req.prompt_len + req.max_new_tokens
            if self.pool is not None:
                if getattr(self.pool, "prefix_cache", False):
                    toks = np.asarray(req.tokens).reshape(-1)
                    info = self.pool.try_reserve(i, total, tokens=toks)
                    if info is None:
                        self.page_stalls += 1
                        self._emit_stall(req)
                        break
                    if info.shared_pages:
                        self.prefix_hits += 1
                        self.shared_pages += info.shared_pages
                else:
                    if not self.pool.can_admit(total):
                        self.page_stalls += 1
                        self._emit_stall(req)
                        break
                    self.pool.reserve(i, total)
            self._pending.remove(req)
            self._slots[i] = _Slot(rid=req.rid, pos=req.prompt_len,
                                   remaining=req.max_new_tokens)
            self.req_log[req.rid]["admit_step"] = self.now
            out.append((i, req))
            obs_trace.instant("serve/sched/admit",
                              args={"rid": req.rid, "slot": i,
                                    "step": self.now})
            reg = obs_metrics.get()
            if reg is not None:
                reg.counter("serve/sched/admitted").inc()
        self.peak_active = max(self.peak_active, sum(
            s is not None for s in self._slots))
        return out

    def _emit_stall(self, req: Request) -> None:
        """Observability: an admission deferred for pages (outcome
        timeline, not just the final page_stalls count)."""
        obs_trace.instant("serve/sched/page_stall",
                          args={"rid": req.rid, "step": self.now})
        reg = obs_metrics.get()
        if reg is not None:
            reg.counter("serve/sched/page_stalls").inc()

    def arrived_pending(self) -> list[int]:
        """rids of queued requests whose arrival step has been reached
        (admissible now, waiting for a slot/pages) — the set whose
        queue-wait clock is running."""
        return [r.rid for r in self._pending if r.arrival <= self.now]

    def slot_rids(self) -> list[int | None]:
        """Per-slot resident rid (None for free slots)."""
        return [None if s is None else s.rid for s in self._slots]

    def started(self, slot: int, first_token: int) -> bool:
        """Record the prefill-sampled first token. Returns False when
        the request is already complete (max_new_tokens == 1) — the
        engine should evict the slot without decoding it."""
        s = self._slots[slot]
        assert s is not None, "started() on a free slot"
        self.prefills += 1
        s.generated.append(int(first_token))
        s.remaining -= 1
        if s.remaining == 0:
            self._finish(slot)
            return False
        return True

    # -- per-step state the engine feeds the jitted decode ------------------
    def active_mask(self) -> np.ndarray:
        return np.asarray([s is not None for s in self._slots], bool)

    def positions(self) -> np.ndarray:
        """(n_slots,) int32 cache positions; free slots report 0."""
        return np.asarray([0 if s is None else s.pos
                           for s in self._slots], np.int32)

    def advance(self, sampled: np.ndarray) -> list[int]:
        """One decode step ran over the whole batch. ``sampled[i]`` is
        slot i's next token (ignored for free slots). Returns the slots
        freed this step (engine evicts + refills them)."""
        self.now += 1
        self.decode_steps += 1
        freed = []
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            self.active_slot_steps += 1
            s.generated.append(int(np.asarray(sampled[i]).reshape(-1)[0]))
            s.pos += 1
            s.remaining -= 1
            if s.remaining == 0:
                self._finish(i)
                freed.append(i)
        return freed

    def advance_spec(self, committed: dict[int, list[int]]) -> list[int]:
        """One speculative round ran. ``committed[i]`` is the list of
        tokens the rejection sampler committed for slot i this round
        (1..k+1 tokens — every round makes progress). Slots absent from
        ``committed`` were idle this round. Returns freed slots."""
        self.now += 1
        self.decode_steps += 1
        freed = []
        for i, toks in committed.items():
            s = self._slots[i]
            assert s is not None, f"advance_spec on free slot {i}"
            assert 1 <= len(toks) <= s.remaining, \
                f"slot {i}: committed {len(toks)} with {s.remaining} left"
            self.active_slot_steps += 1
            s.generated.extend(int(t) for t in toks)
            s.pos += len(toks)
            s.remaining -= len(toks)
            if s.remaining == 0:
                self._finish(i)
                freed.append(i)
        return freed

    def idle_tick(self) -> None:
        """Nothing active and nothing arrived: jump the clock to the
        next arrival instead of burning empty decode steps."""
        nxt = min((r.arrival for r in self._pending), default=self.now + 1)
        self.idle_steps += max(nxt - self.now, 1)
        self.now = max(nxt, self.now + 1)

    def _finish(self, slot: int) -> None:
        s = self._slots[slot]
        self.results[s.rid] = s.generated
        self.req_log[s.rid]["finish_step"] = self.now
        self._slots[slot] = None
        if self.pool is not None:
            self.pool.release(slot)

    # -- reporting -----------------------------------------------------------
    def occupancy(self) -> float:
        """Achieved slot occupancy over decode steps: 1.0 means every
        slot held a live request on every step the batch decoded."""
        total = self.decode_steps * self.n_slots
        return self.active_slot_steps / total if total else 0.0

    def slo_report(self, step_time_us: float) -> dict:
        """Per-request TTFT/latency percentiles + SLO attainment under
        a per-step cost model (``step_time_us`` per decode step — the
        dryrun feeds its roofline step time here, tests feed 1.0).

        Step accounting: the prefill that produces the first token runs
        inside the admit step, so ``ttft = admit - arrival + 1`` steps
        and ``latency = finish - arrival + 1`` (a prefill-only request
        costs exactly one step). Attainment counts only requests that
        carry a ``deadline_us`` (None when no request does).
        """
        ttft, lat, per_req = [], [], {}
        met = deadlines = 0
        for rid, log in sorted(self.req_log.items()):
            if "admit_step" not in log or "finish_step" not in log:
                continue                       # still pending/active
            t = (log["admit_step"] - log["arrival"] + 1) * step_time_us
            lt = (log["finish_step"] - log["arrival"] + 1) * step_time_us
            ttft.append(t)
            lat.append(lt)
            ok = None
            if log["deadline_us"] is not None:
                deadlines += 1
                ok = bool(lt <= log["deadline_us"])
                met += ok
            per_req[rid] = {"ttft_us": round(t, 3),
                            "latency_us": round(lt, 3), "met": ok}

        def pct(a, q):
            return round(float(np.percentile(a, q)), 3) if a else 0.0

        return {
            "step_time_us": step_time_us,
            "requests": len(lat),
            "ttft_us": {"p50": pct(ttft, 50), "p99": pct(ttft, 99)},
            "latency_us": {"p50": pct(lat, 50), "p99": pct(lat, 99)},
            "deadlines": deadlines,
            "attainment": (round(met / deadlines, 4)
                           if deadlines else None),
            "per_request": per_req,
        }

    def stats(self) -> dict:
        out = {
            "slots": self.n_slots,
            "requests": len(self.results),
            "generated_tokens": sum(len(v) for v in self.results.values()),
            "prefills": self.prefills,
            "decode_steps": self.decode_steps,
            "idle_steps": self.idle_steps,
            "peak_active": self.peak_active,
            "occupancy": round(self.occupancy(), 4),
            # the step clock when the last request finished — the
            # makespan the router's load-aware projection minimizes
            "final_step": self.now,
        }
        if self.pool is not None:
            out["page_stalls"] = self.page_stalls
            if getattr(self.pool, "prefix_cache", False):
                out["prefix_hits"] = self.prefix_hits
                out["shared_pages"] = self.shared_pages
            out["paging"] = self.pool.summary()
        return out


def simulate_admission(n_slots: int, requests: list[Request],
                       pool=None, step_time_us: float | None = None
                       ) -> dict:
    """Modelless replay of the admission policy: how well do ``n_slots``
    stay occupied for this trace? Used by launch/dryrun.py to record the
    achieved occupancy a decode cell's slot count implies, by the serve
    router's load-aware placement, and by tests (no devices, no model —
    pure host bookkeeping).

    With a ``pool`` (:class:`repro.serve.paging.PagePool`) the replay
    also drives page reservation/growth/release exactly as the engine
    would, so the returned stats carry page occupancy and internal
    fragmentation for the trace — the dryrun ``serve.paged`` record.

    With ``step_time_us`` (a per-step cost model, e.g. the dryrun's
    roofline step time) the stats gain a ``"slo"`` record: per-request
    TTFT/latency percentiles and deadline attainment
    (:meth:`SlotScheduler.slo_report`).
    """
    sched = SlotScheduler(n_slots, pool=pool)
    for r in requests:
        sched.submit(r)
    guard = sum(r.max_new_tokens for r in requests) + sum(
        r.arrival for r in requests) + len(requests) + 1
    while sched.has_work():
        for slot, req in sched.admit():
            if pool is not None:
                pool.cow_if_needed(slot)
                pool.ensure(slot, req.prompt_len)
                pool.register_prefix(slot,
                                     np.asarray(req.tokens).reshape(-1))
            sched.started(slot, 0)
        if not sched.active_mask().any():
            sched.idle_tick()
            continue
        if pool is not None:
            active = sched.active_mask()
            pos = sched.positions()
            for i in range(n_slots):
                if active[i]:
                    pool.ensure(i, int(pos[i]) + 1)
            pool.tick()
        sched.advance(np.zeros(n_slots, np.int64))
        guard -= 1
        if guard < 0:  # pragma: no cover - scheduler invariant broken
            raise RuntimeError("simulate_admission did not terminate")
    stats = sched.stats()
    if step_time_us is not None:
        stats["slo"] = sched.slo_report(step_time_us)
    return stats


__all__ = [
    "Request", "SlotScheduler", "simulate_admission",
    "cache_len_of", "fit_cache_len", "grow_cache",
    "insert_slot_cache", "insert_paged_cache", "insert_paged_span",
    "copy_page_cache", "evict_slot", "evict_slot_state",
]
