"""Speculative decoding with a CSB-pruned self-draft.

CSB-RNN's thesis is that compressed-structured-block pruning keeps model
quality at high compression — exactly the property a *draft* model
needs. Here the draft IS the target checkpoint run through the paper's
own projection (``core.pruning.csb_project`` at ``draft_prune_rate``):
no second checkpoint, no distillation. Each round the draft proposes
``spec_k`` tokens autoregressively (cheap single-token steps); the
target scores all of them in ONE multi-position decode step (the
vector-pos paged step generalized to s = k+1 query positions, see
``models.layers._decode_mask``); standard rejection sampling
[Leviathan et al. 2023] then commits a prefix of the proposals plus one
target-sampled token, so the committed stream is distributed EXACTLY as
target-only decoding at any temperature — and token-for-token identical
at temperature 0, where acceptance degenerates to ``draft == argmax``.

Every round commits between 1 and spec_k+1 tokens: acceptance rate is
the speed knob, and ``draft_prune_rate`` trades draft cost against it
(rate 0 is the parity configuration: the draft equals the target and
essentially everything is accepted).

Cache bookkeeping per round (slot at committed frontier p, last
committed-but-unwritten token ``cur``):

- draft: k contiguous single-token steps write [cur, d_1..d_{k-1}] at
  p..p+k-1 and sample d_1..d_k. Stale draft KV past a rejection is
  overwritten next round before any query can attend it.
- target: one (k+1)-wide paged step writes [cur, d_1..d_k] at p..p+k
  and returns per-position logits pi_0..pi_k.
- commit n in [1, k_eff+1] tokens; ``PagePool.truncate(slot, p+n)``
  rolls the page table back past the first rejected position (frees
  whole tail pages; the mixed boundary page is masked, not zeroed).

RNG discipline: every sampling decision is keyed by
``fold_in(rng, rid) -> fold_in(., token_index) -> fold_in(., purpose)``
(purpose: proposal/bonus sample, accept-u, residual resample) — NO
round counter. The same token index draws the same key whatever spec_k
is, which is what makes the temperature>0 parity test (spec_k=N vs
spec_k=1 at prune rate 0, same rng) an equality check instead of a
statistical one.
"""
from __future__ import annotations

import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CSBSpec, csb_project
from repro.models import ModelConfig
from repro.models import lm as LM
from repro.obs import metrics as obs_metrics, trace as obs_trace

from .config import EngineConfig
from .engine import ServeResult, _Runner, _sampler, bucket_len
from .paging import PagePool, pages_for
from .scheduler import (
    SlotScheduler, cache_len_of, evict_slot, evict_slot_state,
    fit_cache_len, grow_cache, insert_paged_cache, insert_slot_cache,
)

PyTree = Any

# fold_in purposes (see module docstring)
_SAMPLE, _ACCEPT, _RESID = 0, 1, 2


def derive_draft_params(params: PyTree, prune_rate: float, *,
                        bm: int = 32, bn: int = 32) -> PyTree:
    """The self-draft: CSB-project every layer weight matrix of the
    target checkpoint at ``prune_rate`` (Algorithm 1's two-pass
    row/column projection). Embeddings, heads and norm scales stay
    intact — pruning acts on the MVM weights the paper's engine
    accelerates. ``prune_rate=0`` returns ``params`` unchanged (the
    bit-identical parity draft)."""
    if prune_rate <= 0.0:
        return params
    spec = CSBSpec(bm=bm, bn=bn, prune_rate=float(prune_rate))

    def one(path, leaf):
        name = getattr(path[-1], "key", "")
        if getattr(leaf, "ndim", 0) in (2, 3) and name.startswith("w"):
            return csb_project(leaf, spec)
        return leaf

    return jax.tree_util.tree_map_with_path(one, params)


def _key(base: jax.Array, rid: int, index: int, purpose: int) -> jax.Array:
    k = jax.random.fold_in(base, rid)
    k = jax.random.fold_in(k, index)
    return jax.random.fold_in(k, purpose)


def _categorical(key, logits, temperature: float) -> int:
    return int(jax.random.categorical(
        key, jnp.asarray(logits, jnp.float32) / temperature))


def _commit_round(base_rng, rid: int, p: int, drafts, q_log, pi_log,
                  k_eff: int, temperature: float) -> list[int]:
    """Rejection-sample one verify round for one sequence.

    ``drafts``: k proposed tokens (only the first ``k_eff`` are
    eligible); ``q_log``: (k, V) draft logits; ``pi_log``: (k+1, V)
    target logits, row j scoring the token at index ``p + 1 + j``.
    Returns the committed tokens — a prefix of the accepted drafts plus
    exactly one target-sampled token (correction on first rejection,
    bonus on full acceptance), so every round progresses.
    """
    if temperature <= 0.0:
        tgt = np.argmax(np.asarray(pi_log), axis=-1)
        out = []
        for j in range(k_eff):
            if int(drafts[j]) != int(tgt[j]):
                out.append(int(tgt[j]))          # correction
                return out
            out.append(int(drafts[j]))
        out.append(int(tgt[k_eff]))              # bonus
        return out
    out = []
    for j in range(k_eff):
        idx = p + 1 + j
        d = int(drafts[j])
        pi_p = jax.nn.softmax(jnp.asarray(pi_log[j], jnp.float32)
                              / temperature)
        q_p = jax.nn.softmax(jnp.asarray(q_log[j], jnp.float32)
                             / temperature)
        u = float(jax.random.uniform(_key(base_rng, rid, idx, _ACCEPT)))
        ratio = float(pi_p[d]) / max(float(q_p[d]), 1e-30)
        if u < ratio:
            out.append(d)
            continue
        # first rejection: resample the residual norm(max(pi - q, 0)).
        # With a near-perfect draft the residual mass underflows —
        # fall back to pi itself (the distributions coincide there).
        res = jnp.clip(pi_p - q_p, 0.0)
        tot = float(res.sum())
        rkey = _key(base_rng, rid, idx, _RESID)
        if tot < 1e-9:
            out.append(_categorical(rkey, pi_log[j], temperature))
        else:
            out.append(int(jax.random.categorical(rkey, jnp.log(res))))
        return out
    idx = p + 1 + k_eff
    out.append(_categorical(_key(base_rng, rid, idx, _SAMPLE),
                            pi_log[k_eff], temperature))
    return out


def _propose(drf: _Runner, d_cache, cur, pos, live, rids, k: int,
             temperature: float, base_rng):
    """Run k+1 draft steps from frontier ``pos`` (B,) feeding ``cur``
    (B,). Returns (proposals (k, B), draft logits (k, B, V), new draft
    cache). The extra (k+1)-th step samples nothing — it writes d_k's
    KV into the draft cache so a fully-accepted round (frontier jumps
    to p+k+1 past the bonus token) leaves no unwritten position behind;
    after a rejection the write is stale and the next round overwrites
    it before any query attends it.
    """
    b = cur.shape[0]
    drafts = np.zeros((k, b), np.int64)
    q_logs = []
    dcur = np.asarray(cur, np.int64)
    for j in range(k + 1):
        posv = drf.place_pos(jnp.asarray(pos + j, jnp.int32))
        toks = drf.place_tokens(jnp.asarray(dcur[:, None], jnp.int32))
        lg, d_cache = drf.step(d_cache, toks, posv)
        if j == k:
            break                      # KV catch-up write only
        ql = np.asarray(lg[:, -1], np.float32)        # (B, V)
        if temperature <= 0.0:
            nxt = np.argmax(ql, axis=-1)
        else:
            nxt = np.array([
                _categorical(_key(base_rng, int(rids[i]),
                                  int(pos[i]) + 1 + j, _SAMPLE),
                             ql[i], temperature) if live[i] else 0
                for i in range(b)], np.int64)
        drafts[j] = nxt
        q_logs.append(ql)
        dcur = nxt
    return drafts, np.stack(q_logs), d_cache


# ---------------------------------------------------------------------------
# fixed-batch speculative generate
# ---------------------------------------------------------------------------

def generate_speculative(params, cfg: ModelConfig, tokens,
                         scfg: EngineConfig,
                         rng: jax.Array | None = None, *,
                         mesh=None, policy=None):
    """Speculative twin of :func:`repro.serve.engine.generate`:
    same (B, S+new) output contract, token-for-token identical at
    temperature 0. Contiguous caches for both models; per-row frontiers
    advance by variable acceptance, rows re-verify harmlessly once done.
    """
    if cfg.n_codebooks:
        raise NotImplementedError(
            "speculative decoding drives single-stream token ids")
    if cfg.mixer not in ("attn", "mla"):
        raise NotImplementedError(
            "speculative decoding needs a per-position KV cache "
            f"(attn/mla), not mixer={cfg.mixer!r}")
    tokens = jnp.asarray(tokens)
    b, s = tokens.shape[:2]
    k, max_new = scfg.spec_k, scfg.max_new_tokens
    temperature = scfg.temperature
    # + k slack: the widest verify writes p..p+k and the contiguous
    # dynamic_update_slice clamps its start instead of scattering, so
    # the cache must physically hold the overhang
    total = (scfg.cache_len or (s + max_new)) + k
    rng = rng if rng is not None else jax.random.PRNGKey(0)

    tgt = _Runner(params, cfg, mesh, policy)
    drf = _Runner(derive_draft_params(params, scfg.draft_prune_rate),
                  cfg, mesh, policy)

    t_log, t_cache = tgt.prefill(tokens)
    t_cache = tgt.place_cache(
        grow_cache(t_cache, total - cache_len_of(t_cache)))
    _, d_cache = drf.prefill(tokens)
    d_cache = drf.place_cache(
        grow_cache(d_cache, total - cache_len_of(d_cache)))

    sample = _sampler(cfg, temperature)
    first = np.asarray(sample(t_log, rng)).reshape(-1)
    out = [[int(t)] for t in first]
    pos = np.full(b, s, np.int64)
    cur = first.astype(np.int64)
    rids = np.arange(b)
    proposed = accepted = rounds = 0
    while any(len(o) < max_new for o in out):
        remaining = np.asarray([max_new - len(o) for o in out])
        live = remaining > 0
        drafts, q_logs, d_cache = _propose(
            drf, d_cache, cur, pos, live, rids, k, temperature, rng)
        verify = np.concatenate([cur[:, None], drafts.T], axis=1)
        lg, t_cache = tgt.step(
            t_cache, tgt.place_tokens(jnp.asarray(verify, jnp.int32)),
            tgt.place_pos(jnp.asarray(pos, jnp.int32)))
        pi = np.asarray(lg, np.float32)              # (B, k+1, V)
        rounds += 1
        for i in range(b):
            if not live[i]:
                continue
            k_eff = min(k, int(remaining[i]) - 1)
            committed = _commit_round(rng, int(rids[i]), int(pos[i]),
                                      drafts[:, i], q_logs[:, i], pi[i],
                                      k_eff, temperature)
            out[i].extend(committed)
            pos[i] += len(committed)
            cur[i] = committed[-1]
            proposed += k_eff
            accepted += len(committed) - 1
    gen = jnp.asarray([o[:max_new] for o in out], jnp.int32)
    return jnp.concatenate([tokens, gen.astype(tokens.dtype)], axis=1)


def _spec_stats(scfg: EngineConfig, rounds: int, proposed: int,
                accepted: int) -> dict:
    return {
        "spec_k": scfg.spec_k,
        "draft_prune_rate": scfg.draft_prune_rate,
        "rounds": rounds,
        "proposed": proposed,
        "accepted": accepted,
        "acceptance_rate": round(accepted / proposed, 4) if proposed
        else 1.0,
    }


# ---------------------------------------------------------------------------
# continuous-batching speculative serve
# ---------------------------------------------------------------------------

def serve_continuous_speculative(params, cfg: ModelConfig, requests,
                                 config: EngineConfig, *,
                                 mesh=None, policy=None,
                                 rng: jax.Array | None = None
                                 ) -> ServeResult:
    """Speculative twin of ``serve_continuous`` (dispatched from there
    when ``config.speculative``). Paged target cache + contiguous draft
    cache; admission, bucketing and eviction mirror the plain engine so
    temperature-0 tokens are identical to it. Requires ``paged=True``:
    per-slot variable acceptance is a page-table rollback
    (``PagePool.truncate``) — the contiguous engine has no object to
    roll back.
    """
    if cfg.n_codebooks:
        raise NotImplementedError(
            "speculative decoding drives single-stream token ids")
    if cfg.mixer not in ("attn", "mla"):
        raise NotImplementedError(
            "speculative decoding needs a per-position KV cache "
            f"(attn/mla), not mixer={cfg.mixer!r}")
    if not config.paged:
        raise ValueError("speculative serve_continuous requires "
                         "config.paged=True (rollback is a page-table "
                         "truncate)")
    n_slots, k = config.n_slots, config.spec_k
    temperature = config.temperature
    page_size, pool_pages = config.page_size, config.pool_pages
    use_kernel = config.use_kernel
    bucket = (config.bucket_prompts if config.bucket_prompts is not None
              else True)
    if not requests:
        stats = SlotScheduler(n_slots).stats()
        stats.update(cache_len=0, tokens_per_sec=0.0, paged=True,
                     bucketed_prefill=bucket, prefix_cache=False,
                     prefill_tokens=0, compile_time_s=0.0,
                     steady_tokens_per_sec=0.0, sharded=False,
                     speculative=_spec_stats(config, 0, 0, 0))
        stats["paging"] = PagePool(
            page_size, 1 if pool_pages is None else pool_pages,
            n_slots, 1).summary()
        stats["page_stalls"] = 0
        return ServeResult({}, stats, 0.0)

    cache_len = config.cache_len or max(
        r.prompt_len + r.max_new_tokens for r in requests)
    short = [r for r in requests
             if r.prompt_len + r.max_new_tokens > cache_len]
    if short:
        raise ValueError(
            f"cache_len={cache_len} cannot hold request(s) "
            f"{[r.rid for r in short]}")

    tgt = _Runner(params, cfg, mesh, policy)
    drf = _Runner(derive_draft_params(params, config.draft_prune_rate),
                  cfg, mesh, policy)
    sample = _sampler(cfg, temperature)
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    # decode keys must come from a FIXED base: ``rng`` itself mutates on
    # every admission split, and how many admissions precede a given
    # round depends on spec_k (fewer rounds -> arrivals land elsewhere),
    # which would break the k-invariant key schedule
    dec_rng = jax.random.fold_in(rng, 0x5bec)

    # the verify step writes up to k positions past a slot's committed
    # frontier: widen the page-table logical width so those positions
    # map to real table entries (unmapped -> scratch) instead of
    # clipping back into the slot's last mapped page
    max_pages = pages_for(cache_len + k, page_size)
    n_pool = n_slots * max_pages if pool_pages is None else pool_pages
    pool = PagePool(page_size, n_pool, n_slots, max_pages)
    sched = SlotScheduler(n_slots, pool=pool)
    for r in requests:
        sched.submit(r)

    t_cache = tgt.place_cache(
        LM.init_paged_cache(cfg, pool.n_pages, page_size, n_slots,
                            jnp.dtype(cfg.dtype)), paged=True)
    # contiguous draft cache, + k slack for the round's proposal writes
    d_cache = drf.place_cache(
        LM.init_cache(cfg, n_slots, cache_len + k, jnp.dtype(cfg.dtype)))
    cur = np.zeros(n_slots, np.int64)
    rid_of = np.zeros(n_slots, np.int64)
    table_host = table_placed = None
    tr = obs_trace.get()
    reg = obs_metrics.get()
    prefill_tokens = 0
    compile_ns = steady_ns = steady_tokens = 0
    proposed = accepted = rounds = 0

    t0 = time.perf_counter()
    while sched.has_work():
        for slot, req in sched.admit():
            rng, kk = jax.random.split(rng)
            toks = np.asarray(req.tokens)
            plen = req.prompt_len
            t_pf = time.perf_counter_ns()
            if bucket:
                padded = np.pad(toks, [(0, bucket_len(plen) - plen)])
                logits, req_cache = tgt.prefill(
                    jnp.asarray(padded)[None], last_pos=plen - 1)
                _, d_req = drf.prefill(
                    jnp.asarray(padded)[None], last_pos=plen - 1)
                prefill_tokens += int(padded.shape[0])
            else:
                logits, req_cache = tgt.prefill(jnp.asarray(toks)[None])
                _, d_req = drf.prefill(jnp.asarray(toks)[None])
                prefill_tokens += plen
            first = int(np.asarray(sample(logits, kk)).reshape(-1)[0])
            if tgt.last_cold:
                compile_ns += time.perf_counter_ns() - t_pf
            if sched.started(slot, first):
                pool.ensure(slot, plen)
                phys = list(pool.slot_pages(slot))
                n_pad = 1 << max(len(phys) - 1, 0).bit_length()
                phys += [pool.scratch_page] * (n_pad - len(phys))
                req_cache = fit_cache_len(req_cache, len(phys) * page_size)
                t_cache = insert_paged_cache(
                    t_cache, tgt.place_slot_cache(req_cache), phys, slot)
                d_cache = insert_slot_cache(
                    d_cache, drf.place_slot_cache(
                        fit_cache_len(d_req, plen)), slot)
                cur[slot] = first
                rid_of[slot] = req.rid
        active = sched.active_mask()
        if not active.any():
            sched.idle_tick()
            continue
        pos_host = sched.positions().astype(np.int64)
        remaining = np.asarray([
            0 if s is None else s.remaining for s in sched._slots])
        t_st = time.perf_counter_ns()
        drafts, q_logs, d_cache = _propose(
            drf, d_cache, cur, pos_host, active, rid_of, k,
            temperature, dec_rng)
        # map pages for every position this round's verify writes,
        # capped at the slot's lifetime token count (overhang positions
        # past the cap land on scratch / the masked boundary page)
        for i in np.flatnonzero(active):
            pool.ensure(int(i), int(min(pos_host[i] + k + 1,
                                        pos_host[i] + remaining[i])))
        pool.tick()
        fresh = pool.device_table()
        if fresh is not table_host:
            table_host = fresh
            table_placed = tgt.place_table(fresh)
        verify = np.concatenate([cur[:, None], drafts.T], axis=1)
        lg, t_cache = tgt.step_paged(
            t_cache, tgt.place_tokens(jnp.asarray(verify, jnp.int32)),
            tgt.place_pos(jnp.asarray(pos_host, jnp.int32)),
            table_placed, use_kernel=use_kernel)
        pi = np.asarray(lg, np.float32)
        rounds += 1
        committed: dict[int, list[int]] = {}
        for i in np.flatnonzero(active):
            k_eff = min(k, int(remaining[i]) - 1)
            toks = _commit_round(dec_rng, int(rid_of[i]), int(pos_host[i]),
                                 drafts[:, i], q_logs[:, i], pi[i],
                                 k_eff, temperature)
            committed[int(i)] = toks
            proposed += k_eff
            accepted += len(toks) - 1
            # roll the page table back past the last committed write
            pool.truncate(int(i), int(pos_host[i]) + len(toks))
            cur[i] = toks[-1]
        t_en = time.perf_counter_ns()
        n_committed = sum(len(t) for t in committed.values())
        if tgt.last_cold or drf.last_cold:
            compile_ns += t_en - t_st
        else:
            steady_ns += t_en - t_st
            steady_tokens += n_committed
        if tr is not None:
            tr.complete("serve/spec_round", t_st, t_en - t_st,
                        track="engine",
                        args={"committed": n_committed,
                              "active": int(active.sum())})
        if reg is not None:
            reg.histogram("serve/spec/tokens_per_round").observe(
                n_committed)
        for slot in sched.advance_spec(committed):
            t_cache = evict_slot_state(t_cache, slot)
            d_cache = evict_slot(d_cache, slot)
    jax.block_until_ready(t_cache)
    wall = time.perf_counter() - t0

    stats = sched.stats()
    stats["cache_len"] = cache_len
    stats["paged"] = True
    stats["bucketed_prefill"] = bucket
    stats["prefix_cache"] = False
    stats["prefill_tokens"] = prefill_tokens
    stats["tokens_per_sec"] = round(
        stats["generated_tokens"] / wall, 3) if wall > 0 else 0.0
    stats["compile_time_s"] = round(compile_ns / 1e9, 6)
    stats["steady_tokens_per_sec"] = round(
        steady_tokens / (steady_ns / 1e9), 3) if steady_ns > 0 else 0.0
    stats["sharded"] = tgt.mesh is not None
    stats["speculative"] = _spec_stats(config, rounds, proposed, accepted)
    stats["paging"] = pool.summary()
    return ServeResult(sched.results, stats, wall)


__all__ = ["derive_draft_params", "generate_speculative",
           "serve_continuous_speculative"]
