"""Batched serving.

``generate`` — prefill a batch of prompts, then greedy/temperature decode
with the jitted single-token step (the decode_32k / long_500k workload).

``rnn_serve_frames`` — the paper's own serving shape: frame-by-frame RNN
inference (one MVM-bound cell step per frame) with CSB-compressed
weights; returns per-frame outputs and the wall-clock per frame so the
faster-than-realtime criterion (<500 us/frame for speech) can be checked
on real hardware.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.cells import CellGraph, cell_apply, init_state
from repro.models import ModelConfig
from repro.models import lm as LM

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 => greedy
    cache_len: int | None = None  # default: prompt + new tokens


def generate(params, cfg: ModelConfig, tokens, scfg: ServeConfig,
             rng: jax.Array | None = None):
    """tokens: (B, S_prompt) (or (B, S, K) codebooks). Returns (B, S+new)."""
    b, s = tokens.shape[:2]
    total = scfg.cache_len or (s + scfg.max_new_tokens)

    logits, cache = jax.jit(partial(LM.prefill, cfg=cfg))(
        params, {"tokens": tokens})
    # right-size the cache for the decode loop
    need = total - cache_len_of(cache)
    if need > 0:
        cache = grow_cache(cache, need)

    step_jit = jax.jit(partial(LM.decode_step, cfg=cfg))

    def sample(lg, key):
        if scfg.temperature <= 0.0:
            return jnp.argmax(lg, axis=-1)
        return jax.random.categorical(key, lg / scfg.temperature, axis=-1)

    rng = rng if rng is not None else jax.random.PRNGKey(0)
    out = [tokens]
    cur = sample(logits, rng)[:, None]
    if cfg.n_codebooks and cur.ndim == 2:
        cur = cur[:, None]
    for i in range(scfg.max_new_tokens):
        out.append(cur)
        rng, k = jax.random.split(rng)
        lg, cache = step_jit(params, cache, cur, jnp.asarray(s + i))
        cur = sample(lg[:, -1] if not cfg.n_codebooks else lg[:, -1],
                     k)[:, None]
        if cfg.n_codebooks and cur.ndim == 2:
            cur = cur[:, None]
    return jnp.concatenate(out, axis=1)


def cache_len_of(cache: PyTree) -> int:
    for path, leaf in jax.tree_util.tree_leaves_with_path(cache):
        keys = [getattr(k, "key", "") for k in path]
        if keys and keys[-1] in ("k", "v", "c_kv"):
            return leaf.shape[2]   # (L, B, T, ...)
    return 0


def grow_cache(cache: PyTree, extra: int) -> PyTree:
    def grow(path, leaf):
        keys = [getattr(k, "key", "") for k in path]
        if keys and keys[-1] in ("k", "v", "c_kv", "k_rope") and leaf.ndim >= 3:
            pad = [(0, 0)] * leaf.ndim
            pad[2] = (0, extra)
            return jnp.pad(leaf, pad)
        return leaf

    return jax.tree_util.tree_map_with_path(grow, cache)


def rnn_serve_frames(graph: CellGraph, params: PyTree, frames,
                     state: PyTree | None = None, warmup: int = 2):
    """frames: (T, B, in_dim). Weights may be dense or PaddedCSB.

    Returns (outputs (T,B,H), final state, us_per_frame)."""
    if state is None:
        state = init_state(graph, frames.shape[1:-1], jnp.float32)

    @jax.jit
    def step(p, st, x):
        y, st2 = cell_apply(graph, p, x, st)
        return y, st2

    # warmup / compile
    for _ in range(warmup):
        y, _ = step(params, state, frames[0])
    y.block_until_ready()

    outs = []
    t0 = time.perf_counter()
    st = state
    for t in range(frames.shape[0]):
        y, st = step(params, st, frames[t])
        outs.append(y)
    jax.block_until_ready(outs[-1])
    dt = time.perf_counter() - t0
    us_per_frame = dt / frames.shape[0] * 1e6
    return jnp.stack(outs), st, us_per_frame
