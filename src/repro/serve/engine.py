"""Sharded batched + continuous serving.

``generate`` — prefill a batch of prompts, then greedy/temperature decode
with the jitted single-token step (the decode_32k / long_500k workload).

``serve_continuous`` — the production shape: a fixed batch of decode
*slots* fed by :class:`repro.serve.scheduler.SlotScheduler`. Requests
with mixed prompt lengths arrive over time; a finished request's slot is
evicted and the next queued prompt prefilled into it mid-decode, so the
jitted step (compiled once) keeps every slot busy. ``paged=True`` backs
the slots with the ``serve.paging`` block pool (admission by free
pages, page-table decode, pow2 prompt-bucketed prefill) instead of
contiguous worst-case-length slot caches.

``rnn_serve_frames`` — the paper's own serving shape: frame-by-frame RNN
inference (one MVM-bound cell step per frame) with CSB-compressed
weights; returns per-frame outputs and the wall-clock per frame so the
faster-than-realtime criterion (<500 us/frame for speech) can be checked
on real hardware.

All three run under the ``dist`` sharding rules: pass ``mesh=`` (or call
inside a ``use_rules`` scope whose Rules carry a mesh) and parameters
are placed via ``param_specs``/``csb_shard_specs`` on the "model" axis
(CSB weights route through ``csb_matvec_sharded``), while the decode
cache and token batch shard over the "data" axes via
``cache_specs``/``batch_specs`` — the data axes act as a replica set
for continuous batching, each replica carrying its share of the slots.
Without a mesh everything degrades to the single-device paths the CPU
tests use.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.cells import CellGraph, cell_apply, init_state
from repro.dist import (
    Rules, ShardingPolicy, activation_rules, batch_specs, cache_specs,
    csb_shard_specs, current_rules, fit_spec, use_rules,
)
from repro.models import ModelConfig
from repro.models import lm as LM
from repro.obs import metrics as obs_metrics, trace as obs_trace

from .config import EngineConfig, resolve_config
from .paging import PagePool, pages_for
from .scheduler import (
    _TIME_KEYS, Request, SlotScheduler, cache_len_of, copy_page_cache,
    evict_slot, evict_slot_state, fit_cache_len, grow_cache,
    insert_paged_cache, insert_paged_span, insert_slot_cache,
)

PyTree = Any


def bucket_len(n: int, floor: int = 8) -> int:
    """Smallest power of two >= max(n, floor): the prefill-shape bucket.

    Padding prompts up to pow2 buckets bounds the number of compiled
    prefill executables at O(log max_len) for arbitrary length traces
    (the floor merges the tiny lengths into one bucket)."""
    return 1 << max(max(n, floor) - 1, 0).bit_length()


def _resolve_mesh(mesh):
    """Explicit mesh arg, else the active Rules' mesh; trivial -> None."""
    if mesh is None:
        mesh = getattr(current_rules(), "mesh", None)
    if mesh is None or math.prod(dict(mesh.shape).values()) <= 1:
        return None
    return mesh


def _dp_spec(mesh, shape: tuple[int, ...], batch_axis: int = 0) -> P:
    """Spec sharding ``batch_axis`` over the non-model (data) axes,
    divisibility-guarded; every other dim replicated."""
    from repro.dist.rules import _dp_entry
    entries: list[Any] = [None] * len(shape)
    entries[batch_axis] = _dp_entry(mesh)
    fitted = fit_spec(P(*entries), shape, mesh)
    return fitted if fitted is not None else P(*([None] * len(shape)))


@functools.lru_cache(maxsize=64)
def _jitted(cfg: ModelConfig, rules_key):
    """Jitted prefill + decode-step wrappers, cached per (cfg, rules)
    so repeated generate/serve_continuous calls (benchmarks, request
    waves) reuse compiled executables instead of retracing. The traced
    program depends on the active Rules (sharding constraints), hence
    ``rules_key`` — (mesh, policy) for derived rules, the caller's
    Rules instance (identity-hashed) for ambient ones, None for the
    inert single-device path; params are call arguments, so fresh
    weights hit the same cache."""
    return {
        "prefill": jax.jit(partial(LM.prefill, cfg=cfg)),
        # prefix-cache hits prefill only the unmatched suffix against the
        # gathered shared pages; variants bounded by (pow2 suffix bucket)
        # x (pow2 context page count)
        "prefill_partial": jax.jit(partial(LM.prefill_partial, cfg=cfg)),
        # one jitted step per pos rank: scalar (fixed batch) / (B,) slots
        "steps": {},
    }


class _Runner:
    """One (params, cfg, mesh, policy) serving context: places the
    parameter tree once, owns the jitted prefill/decode callables, and
    re-installs its Rules around every traced call so model-side
    ``shard()`` tags resolve.

    Rules precedence: an explicit ``mesh=`` derives the canonical
    ``activation_rules`` for it; with no mesh argument, a caller's
    ambient ``use_rules`` scope is honored verbatim — both its mesh and
    its table (a caller that hand-built cache layouts keeps them)."""

    def __init__(self, params, cfg: ModelConfig, mesh=None, policy=None):
        self.cfg = cfg
        # cold-call tracking: ``last_cold`` is True when the preceding
        # prefill/step call compiled (or at least first-traced) its
        # executable — the engine charges that call's wall time to
        # ``compile_time_s`` instead of the steady-state throughput
        self.last_cold = False
        self._seen_keys: set = set()
        ambient = current_rules()
        self.mesh = _resolve_mesh(mesh)
        self.policy = policy or ShardingPolicy()
        if self.mesh is not None:
            if mesh is None and ambient is not None:
                self.rules = ambient
                rules_key: Any = ambient
            else:
                self.rules = activation_rules(cfg, self.mesh, self.policy)
                rules_key = (self.mesh, self.policy)
            specs = csb_shard_specs(params, self.mesh, policy=self.policy)
            self.params = jax.tree.map(
                lambda leaf, sp: jax.device_put(
                    leaf, NamedSharding(self.mesh, sp)), params, specs)
        else:
            # meshless rules are inert for shard(): one shared trace
            self.rules = ambient or Rules({})
            self.params = params
            rules_key = None
        jt = _jitted(cfg, rules_key)
        self._prefill = jt["prefill"]
        self._prefill_partial = jt["prefill_partial"]
        self._steps = jt["steps"]
        # per-shape NamedSharding cache: spec derivation is loop-
        # invariant, and place_tokens/place_pos sit on the per-token
        # path the serve benchmark gates
        self._shardings: dict = {}

    def _batch_sharding(self, key: str, shape) -> NamedSharding | None:
        ck = (key, shape)
        if ck not in self._shardings:
            spec = batch_specs(self.cfg, "decode", self.mesh)[key]
            fitted = fit_spec(spec, shape, self.mesh)
            self._shardings[ck] = (None if fitted is None
                                   else NamedSharding(self.mesh, fitted))
        return self._shardings[ck]

    def _call_cold(self, fn, key, call):
        """Run ``call()`` and set :attr:`last_cold`. jax's jit cache
        size is the exact signal (a growth means this call traced +
        compiled); fall back to first-sight-of-shape-key when the
        private ``_cache_size`` hook is unavailable."""
        sizer = getattr(fn, "_cache_size", None)
        before = None
        if sizer is not None:
            try:
                before = sizer()
            except Exception:
                before = None
        out = call()
        if before is not None:
            try:
                self.last_cold = sizer() > before
            except Exception:
                self.last_cold = key not in self._seen_keys
        else:
            self.last_cold = key not in self._seen_keys
        self._seen_keys.add(key)
        return out

    def prefill(self, tokens: jax.Array, last_pos=None):
        with use_rules(self.rules):
            if last_pos is None:
                return self._call_cold(
                    self._prefill, ("prefill", tokens.shape),
                    lambda: self._prefill(self.params, {"tokens": tokens}))
            return self._call_cold(
                self._prefill, ("prefill", tokens.shape, "lp"),
                lambda: self._prefill(
                    self.params, {"tokens": tokens},
                    last_pos=jnp.asarray(last_pos, jnp.int32)))

    def prefill_partial(self, tokens: jax.Array, ctx: PyTree, start,
                        last_pos):
        """Prefill a prompt suffix against gathered shared-prefix pages
        (``ctx`` rides replicated — same GSPMD workaround as
        :meth:`place_slot_cache`, and it is one request's worth)."""
        ctx = self.place_slot_cache(ctx)
        ctx_len = cache_len_of(ctx)
        with use_rules(self.rules):
            return self._call_cold(
                self._prefill_partial,
                ("prefill_partial", tokens.shape, ctx_len),
                lambda: self._prefill_partial(
                    self.params, {"tokens": tokens}, ctx,
                    start=jnp.asarray(start, jnp.int32),
                    last_pos=jnp.asarray(last_pos, jnp.int32)))

    def place_cache(self, cache: PyTree, paged: bool = False) -> PyTree:
        if self.mesh is None:
            return cache
        specs = cache_specs(self.cfg, cache, self.mesh, self.policy,
                            paged=paged)
        return jax.tree.map(
            lambda leaf, sp: jax.device_put(
                leaf, NamedSharding(self.mesh, sp)), cache, specs)

    def place_table(self, table: jax.Array) -> jax.Array:
        """Page table: replicated — every data replica indexes the whole
        pool (dist.rules cache_specs keeps pool pages data-parallel;
        the table must see all of them)."""
        if self.mesh is None:
            return table
        return jax.device_put(table, NamedSharding(
            self.mesh, P(*([None] * table.ndim))))

    def place_tokens(self, tokens: jax.Array) -> jax.Array:
        if self.mesh is None:
            return tokens
        sh = self._batch_sharding("tokens", tokens.shape)
        return tokens if sh is None else jax.device_put(tokens, sh)

    def place_pos(self, pos: jax.Array) -> jax.Array:
        if self.mesh is None or pos.ndim == 0:
            return pos
        sh = self._batch_sharding("pos", pos.shape)
        return pos if sh is None else jax.device_put(pos, sh)

    def place_slot_cache(self, req_cache: PyTree) -> PyTree:
        """Replicate a freshly prefilled single-request cache before it
        is written into the batch cache. Prefill tags its KV with the
        time-sharded ``kv_cache`` layout; letting GSPMD transition that
        straight into the batch cache's layout inside the jitted insert
        is the involuntary-full-rematerialization path (see
        ``dist.api.shard``) — an explicit host-side replication copy is
        tiny (one request) and keeps the insert a plain masked update."""
        if self.mesh is None:
            return req_cache
        return jax.tree.map(
            lambda leaf: jax.device_put(leaf, NamedSharding(
                self.mesh, P(*([None] * leaf.ndim)))), req_cache)

    def step(self, cache, tokens, pos):
        fn = self._steps.get(jnp.ndim(pos))
        if fn is None:
            fn = jax.jit(partial(LM.decode_step, cfg=self.cfg),
                         donate_argnums=(1,))
            self._steps[jnp.ndim(pos)] = fn
        with use_rules(self.rules):
            return self._call_cold(
                fn, ("step", jnp.ndim(pos)),
                lambda: fn(self.params, cache, tokens, pos))

    def step_paged(self, cache, tokens, pos, page_table,
                   use_kernel: bool = False):
        key = ("paged", jnp.ndim(pos), use_kernel)
        fn = self._steps.get(key)
        if fn is None:
            fn = jax.jit(partial(LM.decode_step_paged, cfg=self.cfg,
                                 use_kernel=use_kernel),
                         donate_argnums=(1,))
            self._steps[key] = fn
        with use_rules(self.rules):
            return self._call_cold(
                fn, key,
                lambda: fn(self.params, cache, tokens, pos, page_table))


def _sampler(cfg: ModelConfig, temperature: float):
    def sample(lg, key):
        if temperature <= 0.0:
            return jnp.argmax(lg, axis=-1)
        return jax.random.categorical(key, lg / temperature, axis=-1)

    return sample


# ---------------------------------------------------------------------------
# fixed-batch generate
# ---------------------------------------------------------------------------

def generate(params, cfg: ModelConfig, tokens,
             config: EngineConfig | None = None,
             rng: jax.Array | None = None, *, mesh=None, policy=None):
    """tokens: (B, S_prompt) (or (B, S, K) codebooks). Returns (B, S+new).

    ``config`` is the unified :class:`EngineConfig`. With a mesh
    (argument or active Rules), params/cache/batch run sharded; results
    match the single-device path token-for-token. With
    ``config.speculative`` a CSB-pruned self-draft proposes
    ``spec_k``-token runs the target verifies in one multi-position
    decode step (see serve.speculative); tokens are identical to the
    plain path at temperature 0.
    """
    scfg = resolve_config(config, caller="generate")
    if scfg.speculative:
        from .speculative import generate_speculative
        return generate_speculative(params, cfg, tokens, scfg, rng,
                                    mesh=mesh, policy=policy)
    b, s = tokens.shape[:2]
    total = scfg.cache_len or (s + scfg.max_new_tokens)
    runner = _Runner(params, cfg, mesh, policy)

    logits, cache = runner.prefill(jnp.asarray(tokens))
    # right-size the cache for the decode loop
    cache = grow_cache(cache, total - cache_len_of(cache))
    cache = runner.place_cache(cache)

    sample = _sampler(cfg, scfg.temperature)
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    out = [jnp.asarray(tokens)]
    cur = sample(logits, rng)[:, None]
    if cfg.n_codebooks and cur.ndim == 2:
        cur = cur[:, None]
    for i in range(scfg.max_new_tokens):
        out.append(cur)
        rng, k = jax.random.split(rng)
        lg, cache = runner.step(cache, runner.place_tokens(cur),
                                jnp.asarray(s + i))
        cur = sample(lg[:, -1], k)[:, None]
        if cfg.n_codebooks and cur.ndim == 2:
            cur = cur[:, None]
    return jnp.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServeResult:
    """Outcome of a continuous-batching run."""

    tokens: dict[int, list[int]]      # rid -> generated token ids
    stats: dict                       # scheduler stats + throughput
    wall_s: float

    @property
    def occupancy(self) -> float:
        return self.stats["occupancy"]

    @property
    def tokens_per_sec(self) -> float:
        return self.stats["tokens_per_sec"]


def _gather_ctx(cache: PyTree, pages) -> PyTree:
    """Pull the shared-prefix pages out of the live paged cache as a
    contiguous per-layer context for the partial prefill. ``pages`` is a
    host array of physical page ids (scratch-padded to a pow2 count, so
    compiled partial-prefill variants stay O(log max_pages)); each time
    leaf (L, N, P, ...) gathers to (L, 1, len(pages) * P, ...)."""
    idx = jnp.asarray(pages, jnp.int32)

    def one(path, leaf):
        keys = [getattr(k, "key", "") for k in path]
        assert keys and keys[-1] in _TIME_KEYS, \
            "prefix sharing needs an all-pool cache (attn/mla)"
        g = leaf[:, idx]
        return g.reshape((g.shape[0], 1, g.shape[1] * g.shape[2])
                         + g.shape[3:])

    return jax.tree_util.tree_map_with_path(one, cache)


def serve_continuous(params, cfg: ModelConfig, requests: list[Request],
                     config: EngineConfig | None = None, *,
                     mesh=None, policy=None,
                     rng: jax.Array | None = None) -> ServeResult:
    """Serve ``requests`` (mixed prompt lengths, arriving over time)
    through ``config.n_slots`` continuously-batched decode slots.

    All engine knobs ride on one :class:`EngineConfig` (serve + paging
    + kernel + prefix + speculative fields, cross-validated at
    construction); loose kwargs raise ``TypeError`` (the one-release
    migration shim is gone).

    The decode step compiles once for the (n_slots, cache_len) shapes
    and runs every step with per-slot positions; admission prefills each
    arrived prompt and writes its cache into the freed slot. Greedy
    decoding (``temperature=0``) matches ``generate`` token-for-token,
    sharded or not, paged or not.

    ``paged=True`` swaps the contiguous per-slot cache for a shared
    pool of ``pool_pages`` fixed-size token pages (``page_size`` each;
    default pool = full contiguous capacity). Slots map logical
    positions to physical pages through a dense page table
    (``serve.paging``); admission goes **by free pages, not free
    slots**, each request reserving only its own worst case — a
    mixed-length trace packs more concurrent requests into the same
    token budget than contiguous slots allow (pass a smaller
    ``pool_pages`` to cap the budget). Pages free mid-decode the moment
    a request finishes.

    ``use_kernel=True`` (paged only) routes decode attention through the
    Pallas paged-attention kernel — the page-table walk happens inside
    the kernel instead of a materialized ``(B, max_pages*P)`` gather;
    sampled tokens are unchanged.

    ``bucket_prompts`` (default: on when paged) right-pads each prompt
    to a pow2 **bucket** before prefill, so a trace of arbitrary
    lengths compiles O(log max_len) prefill executables instead of one
    per distinct length. Causal attention makes right padding invisible
    to real positions, so sampled tokens are unchanged; SSD/hybrid
    mixers scan pad tokens into their recurrent state, so bucketing
    auto-disables there.

    ``prefix_cache=True`` (paged only) retains prompt pages in a
    refcounted radix trie after their request finishes and shares them
    across requests: an admission whose prompt prefix matches pages
    already in the pool maps them instead of recomputing (prefill runs
    only from the divergence point — ``models.lm.prefill_partial``), and
    the first write into a partially-shared page goes through
    copy-on-write. Sampled tokens are identical to ``prefix_cache=False``
    (the partial prefill mirrors the full prefill bit-for-bit at serve
    scales); ``stats["prefix_hits"]``/``stats["shared_pages"]`` count the
    sharing and ``stats["prefill_tokens"]`` the prefill work actually
    done. Auto-disables for SSD/hybrid (their recurrent state has no
    per-position cache to share), like bucketing.

    Throughput accounting: ``stats["tokens_per_sec"]`` divides by the
    FULL wall clock — including the trace+compile of every first-called
    prefill bucket and decode-step variant — and is kept for
    compatibility. ``stats["compile_time_s"]`` isolates that first-call
    (compile-inclusive) time and ``stats["steady_tokens_per_sec"]`` is
    the decode throughput over warm steps only (0.0 when every step was
    cold), so a cold-cache run no longer under-reports the engine.

    With :mod:`repro.obs` enabled the run also emits per-request
    lifecycle spans (queue wait -> prefill -> TTFT -> decode), per-step
    spans and pool/occupancy gauge timelines — see
    docs/observability.md. Disabled (the default), the instrumentation
    is a few branch-on-None checks and never touches the gated
    per-token path.
    """
    if cfg.n_codebooks:
        raise NotImplementedError(
            "serve_continuous drives single-stream token ids; codebook "
            "models go through generate()")
    # invalid combinations (prefix_cache without paged, ...) raise
    # ValueError inside EngineConfig.__post_init__
    config = resolve_config(config, caller="serve_continuous")
    if config.speculative:
        from .speculative import serve_continuous_speculative
        return serve_continuous_speculative(params, cfg, requests, config,
                                            mesh=mesh, policy=policy,
                                            rng=rng)
    n_slots, temperature = config.n_slots, config.temperature
    cache_len, paged = config.cache_len, config.paged
    page_size, pool_pages = config.page_size, config.pool_pages
    use_kernel = config.use_kernel
    bucket = (config.bucket_prompts if config.bucket_prompts is not None
              else paged)
    prefix_cache = config.prefix_cache
    bucket = bucket and cfg.mixer in ("attn", "mla")
    prefix = prefix_cache and cfg.mixer in ("attn", "mla")
    if not requests:
        stats = SlotScheduler(n_slots).stats()
        stats.update(cache_len=0, tokens_per_sec=0.0, paged=paged,
                     bucketed_prefill=bucket, prefix_cache=prefix,
                     prefill_tokens=0, compile_time_s=0.0,
                     steady_tokens_per_sec=0.0,
                     sharded=_resolve_mesh(mesh) is not None)
        if paged:
            stats["paging"] = PagePool(
                page_size, 1 if pool_pages is None else pool_pages,
                n_slots, 1).summary()
            stats["page_stalls"] = 0
        return ServeResult({}, stats, 0.0)
    cache_len = cache_len or max(
        r.prompt_len + r.max_new_tokens for r in requests)
    short = [r for r in requests
             if r.prompt_len + r.max_new_tokens > cache_len]
    if short:
        raise ValueError(
            f"cache_len={cache_len} cannot hold request(s) "
            f"{[r.rid for r in short]}")

    runner = _Runner(params, cfg, mesh, policy)
    sample = _sampler(cfg, temperature)
    rng = rng if rng is not None else jax.random.PRNGKey(0)

    pool = None
    if paged:
        max_pages = pages_for(cache_len, page_size)
        # explicit pool_pages=0 must reject (PagePool raises), not
        # silently fall back to the full contiguous footprint
        n_pool = (n_slots * max_pages if pool_pages is None
                  else pool_pages)
        pool = PagePool(page_size, n_pool, n_slots, max_pages,
                        prefix_cache=prefix)
    sched = SlotScheduler(n_slots, pool=pool)
    for r in requests:
        sched.submit(r)

    if paged:
        cache = runner.place_cache(
            LM.init_paged_cache(cfg, pool.n_pages, page_size, n_slots,
                                jnp.dtype(cfg.dtype)), paged=True)
    else:
        cache = runner.place_cache(
            LM.init_cache(cfg, n_slots, cache_len, jnp.dtype(cfg.dtype)))
    cur = jnp.zeros((n_slots, 1), jnp.int32)
    # device-placed page table, refreshed only when the pool remaps a
    # page (device_table() returns a cached identical object when
    # clean, so identity is the dirty signal) — keeps the redundant
    # host->device put off the gated per-token path
    table_host = table_placed = None

    def _admissions():
        # Under the prefix cache, admit one request at a time: each
        # prompt registers right after its own prefill (below), so the
        # NEXT admission's trie match — even in the same step — can
        # already share it. Without the cache, one batched admit() call
        # keeps the original page_stall accounting.
        if not prefix:
            yield from sched.admit()
            return
        while True:
            batch = sched.admit(limit=1)
            if not batch:
                return
            yield batch[0]

    prefill_tokens = 0
    # observability handles, fetched once per run: ``tr``/``reg`` are
    # None when obs is off and every emit below branches on that —
    # the cold/steady split (compile_ns/steady_*) is ALWAYS accounted,
    # it only costs perf_counter_ns calls around already-blocking work
    tr = obs_trace.get()
    reg = obs_metrics.get()
    obs_on = tr is not None or reg is not None
    req_clock: dict[int, dict] = {}    # rid -> lifecycle timestamps (ns)
    compile_ns = 0
    steady_ns = 0
    steady_tokens = 0

    def _mark_eligible():
        # stamp the wall time each queued request first became
        # admissible (its arrival step reached) — queue wait and TTFT
        # are measured from here, not from engine start
        now_ns = time.perf_counter_ns()
        for rid in sched.arrived_pending():
            req_clock.setdefault(rid, {})["eligible"] = now_ns

    def _finish_req(rid: int, t_fin: int):
        rc = req_clock.get(rid, {})
        t_first = rc.get("first")
        if t_first is None:
            return
        n_dec = len(sched.results.get(rid, ())) - 1
        if tr is not None:
            tr.complete("serve/req/decode", t_first, t_fin - t_first,
                        track=f"req {rid}",
                        args={"rid": rid, "decode_tokens": n_dec})
            tr.instant("serve/req/finish", track=f"req {rid}",
                       args={"rid": rid})
        if reg is not None and n_dec > 0:
            reg.histogram("serve/req/decode_per_token_us").observe(
                (t_fin - t_first) / 1e3 / n_dec)

    t0 = time.perf_counter()
    while sched.has_work():
        if obs_on:
            _mark_eligible()
        for slot, req in _admissions():
            rng, k = jax.random.split(rng)
            tokens = np.asarray(req.tokens)
            plen = req.prompt_len
            if obs_on:
                t_adm = time.perf_counter_ns()
                rc = req_clock.setdefault(req.rid, {})
                t_el = rc.get("eligible", t_adm)
                rc["admit"] = t_adm
                if tr is not None:
                    tr.complete("serve/req/queue_wait", t_el,
                                t_adm - t_el, track=f"req {req.rid}",
                                args={"rid": req.rid, "slot": slot})
                if reg is not None:
                    reg.histogram("serve/req/queue_wait_us").observe(
                        (t_adm - t_el) / 1e3)
            t_pf = time.perf_counter_ns()
            info = pool.shared_info(slot) if prefix else None
            shared = info is not None and info.shared_pages > 0
            if shared:
                # prefix-cache hit: gather the matched pages out of the
                # live pool and prefill only the suffix against them
                sstart = info.suffix_start
                s_real = plen - sstart
                suffix = tokens[sstart:]
                if bucket:
                    suffix = np.pad(
                        suffix, [(0, bucket_len(s_real) - s_real)])
                sp = info.shared_pages
                n_pad = 1 << max(sp - 1, 0).bit_length()
                ctx_row = np.concatenate([
                    pool.slot_row(slot)[:sp],
                    np.full(n_pad - sp, pool.scratch_page, np.int32)])
                logits, req_cache = runner.prefill_partial(
                    jnp.asarray(suffix)[None], _gather_ctx(cache, ctx_row),
                    start=sstart, last_pos=s_real - 1)
                prefill_tokens += int(suffix.shape[0])
            elif bucket:
                pad = bucket_len(plen) - plen
                padded = np.pad(tokens, [(0, pad)] + [(0, 0)] * (
                    tokens.ndim - 1))
                logits, req_cache = runner.prefill(
                    jnp.asarray(padded)[None], last_pos=plen - 1)
                prefill_tokens += int(padded.shape[0])
            else:
                logits, req_cache = runner.prefill(jnp.asarray(tokens)[None])
                prefill_tokens += plen
            first = int(np.asarray(sample(logits, k)).reshape(-1)[0])
            t_ft = time.perf_counter_ns()
            if runner.last_cold:
                compile_ns += t_ft - t_pf
            if obs_on:
                rc = req_clock.setdefault(req.rid, {})
                rc["first"] = t_ft
                t_el = rc.get("eligible", t_pf)
                if tr is not None:
                    track = f"req {req.rid}"
                    tr.complete("serve/req/prefill", t_pf, t_ft - t_pf,
                                track=track,
                                args={"rid": req.rid, "tokens": plen,
                                      "shared": shared,
                                      "cold": runner.last_cold})
                    tr.complete("serve/req/ttft", t_el, t_ft - t_el,
                                track=track, args={"rid": req.rid})
                if reg is not None:
                    reg.histogram("serve/req/prefill_us").observe(
                        (t_ft - t_pf) / 1e3)
                    reg.histogram("serve/req/ttft_us").observe(
                        (t_ft - t_el) / 1e3)
            if sched.started(slot, first):
                if paged:
                    if shared:
                        # divergence inside a shared page: give the slot
                        # a private copy BEFORE the suffix write lands
                        cow = pool.cow_if_needed(slot)
                        if cow is not None:
                            cache = copy_page_cache(cache, *cow)
                        pool.ensure(slot, plen)
                        cache = insert_paged_span(
                            cache, runner.place_slot_cache(req_cache),
                            pool.slot_row(slot), sstart, plen - sstart,
                            slot)
                    else:
                        pool.ensure(slot, plen)
                        phys = list(pool.slot_pages(slot))
                        # pad the page list to a pow2 count with the
                        # scratch page so the jitted insert compiles
                        # O(log max_pages) variants, not one per distinct
                        # prompt page count (scratch swallows the surplus
                        # pad pages harmlessly)
                        n_pad = 1 << max(len(phys) - 1, 0).bit_length()
                        phys += [pool.scratch_page] * (n_pad - len(phys))
                        req_cache = fit_cache_len(
                            req_cache, len(phys) * page_size)
                        cache = insert_paged_cache(
                            cache, runner.place_slot_cache(req_cache),
                            phys, slot)
                    if prefix:
                        # future admissions may now share this prompt
                        pool.register_prefix(slot, tokens)
                else:
                    if bucket:
                        # drop pad positions; decode overwrites each
                        # position before the mask ever exposes it
                        req_cache = fit_cache_len(req_cache, plen)
                    cache = insert_slot_cache(
                        cache, runner.place_slot_cache(req_cache), slot)
                cur = cur.at[slot, 0].set(first)
            elif obs_on:
                # max_new_tokens == 1: finished off the prefill alone;
                # the slot never enters the decode batch
                _finish_req(req.rid, time.perf_counter_ns())
            # (nothing to insert for a prefill-only request)
        active = sched.active_mask()
        if not active.any():
            sched.idle_tick()
            continue
        rng, k = jax.random.split(rng)
        pos_host = sched.positions()
        n_active = int(active.sum())
        rid_by_slot = sched.slot_rids() if obs_on else None
        t_st = time.perf_counter_ns()
        pos = runner.place_pos(jnp.asarray(pos_host))
        if paged:
            # alloc-on-grow: map the page each live slot writes this step
            for i in np.flatnonzero(active):
                pool.ensure(int(i), int(pos_host[i]) + 1)
            pool.tick()
            fresh = pool.device_table()
            if fresh is not table_host:
                table_host = fresh
                table_placed = runner.place_table(fresh)
            lg, cache = runner.step_paged(cache, runner.place_tokens(cur),
                                          pos, table_placed,
                                          use_kernel=use_kernel)
        else:
            lg, cache = runner.step(cache, runner.place_tokens(cur), pos)
        nxt = sample(lg[:, -1], k)
        # the host pull below blocks on the step, so the wall time
        # around it is the true per-step latency (the engine is
        # host-synchronous per token by construction)
        nxt_host = np.asarray(nxt)
        t_en = time.perf_counter_ns()
        if runner.last_cold:
            compile_ns += t_en - t_st
        else:
            steady_ns += t_en - t_st
            steady_tokens += n_active
        if tr is not None:
            tr.complete("serve/decode_step", t_st, t_en - t_st,
                        track="engine",
                        args={"active": n_active,
                              "cold": runner.last_cold})
        if reg is not None:
            reg.histogram("serve/step/wall_us").observe(
                (t_en - t_st) / 1e3)
            reg.gauge("serve/slots/active").set(n_active)
        for slot in sched.advance(nxt_host):
            # pages went back to the allocator inside the scheduler;
            # per-slot SSM/conv state still needs the device-side zero
            cache = (evict_slot_state(cache, slot) if paged
                     else evict_slot(cache, slot))
            if obs_on:
                _finish_req(rid_by_slot[slot], time.perf_counter_ns())
        cur = nxt[:, None].astype(jnp.int32)
    jax.block_until_ready(cache)
    wall = time.perf_counter() - t0

    stats = sched.stats()
    stats["cache_len"] = cache_len
    stats["paged"] = paged
    stats["bucketed_prefill"] = bucket
    stats["prefix_cache"] = prefix
    stats["prefill_tokens"] = prefill_tokens
    # compatibility: tokens_per_sec keeps dividing by the FULL wall
    # clock (compile included); the honest split rides alongside
    stats["tokens_per_sec"] = round(
        stats["generated_tokens"] / wall, 3) if wall > 0 else 0.0
    stats["compile_time_s"] = round(compile_ns / 1e9, 6)
    stats["steady_tokens_per_sec"] = round(
        steady_tokens / (steady_ns / 1e9), 3) if steady_ns > 0 else 0.0
    stats["sharded"] = runner.mesh is not None
    return ServeResult(sched.results, stats, wall)


# ---------------------------------------------------------------------------
# frame-by-frame RNN serving (the paper's workload)
# ---------------------------------------------------------------------------

def shard_cell_params(params: dict, mesh, axis_name: str = "model") -> dict:
    """Cycle-balance every ``PaddedCSB`` cell weight over
    ``mesh[axis_name]`` (``dist.csb_partition``'s greedy planner) and
    place the whole tree with ``csb_shard_specs`` — after this,
    ``cell_apply`` under ``use_rules`` routes each MVM through
    ``csb_matvec_sharded``."""
    from repro.core.csb_format import PaddedCSB
    from repro.dist.csb_partition import partition_padded

    n_dev = mesh.shape[axis_name]
    out = {k: (partition_padded(w, n_dev)[1]
               if isinstance(w, PaddedCSB) else w)
           for k, w in params.items()}
    specs = csb_shard_specs(out, mesh, axis=axis_name)
    return jax.tree.map(
        lambda leaf, sp: jax.device_put(leaf, NamedSharding(mesh, sp)),
        out, specs)


def rnn_serve_frames(graph: CellGraph, params: PyTree, frames,
                     state: PyTree | None = None,
                     warmup: int | None = None,
                     *, config: EngineConfig | None = None, mesh=None,
                     axis_name: str = "model",
                     collect_frame_times: bool | None = None):
    """frames: (T, B, in_dim). Weights may be dense, PaddedCSB, or (with
    a mesh) ShardedCSB.

    ``config.frame_warmup`` / ``config.collect_frame_times`` are the
    :class:`EngineConfig` homes of the two knobs; the positional
    ``warmup`` and ``collect_frame_times`` arguments override them when
    given explicitly (both default to the config).

    With ``mesh=`` (or an active Rules mesh with a non-trivial "model"
    axis) the CSB weights are partitioned over the model axis and the
    frame batch sharded over the data axes, so the per-frame latency is
    measured on the sharded mesh — the paper's faster-than-realtime
    number at multi-chip scale. Returns (outputs (T,B,H), final state,
    us_per_frame).

    ``collect_frame_times=True`` appends a 4th element: a ``(T,)``
    numpy array of per-frame wall microseconds, each frame blocked to
    completion before the next starts. Blocking serializes the device
    pipeline, so the MEAN of these is pessimistic — the un-blocked
    ``us_per_frame`` stays the throughput number; the per-frame vector
    is for tail latency (p99) reporting, where realtime audio cares
    about the worst frame, not the average."""
    fcfg = resolve_config(config, caller="rnn_serve_frames")
    if warmup is None:
        warmup = fcfg.frame_warmup
    if collect_frame_times is None:
        collect_frame_times = fcfg.collect_frame_times
    mesh = _resolve_mesh(mesh)
    rules = current_rules()
    if mesh is not None:
        if axis_name in tuple(mesh.axis_names) \
                and mesh.shape[axis_name] > 1:
            params = shard_cell_params(params, mesh, axis_name)
        frames = jnp.asarray(frames)
        frames = jax.device_put(frames, NamedSharding(    # (T, B, in): B=dp
            mesh, _dp_spec(mesh, frames.shape, batch_axis=1)))
        if rules is None or rules.mesh is not mesh:
            rules = Rules({}, mesh=mesh)
    if rules is None:
        rules = Rules({})

    if state is None:
        state = init_state(graph, frames.shape[1:-1], jnp.float32)

    @jax.jit
    def step(p, st, x):
        y, st2 = cell_apply(graph, p, x, st)
        return y, st2

    with use_rules(rules):
        # warmup / compile
        for _ in range(warmup):
            y, _ = step(params, state, frames[0])
        y.block_until_ready()

        outs = []
        t0 = time.perf_counter()
        st = state
        for t in range(frames.shape[0]):
            y, st = step(params, st, frames[t])
            outs.append(y)
        jax.block_until_ready(outs[-1])
        dt = time.perf_counter() - t0

        frame_us = None
        if collect_frame_times:
            # separate per-frame-blocking pass so the throughput number
            # above is untouched by the serialization; per-frame spans
            # and the realtime histogram (serve/frames/wall_us — the
            # distribution the 500us budget judges) come from HERE,
            # measured times recorded after the fact so tracing adds
            # zero overhead inside the timed region
            tr = obs_trace.get()
            reg = obs_metrics.get()
            times = np.empty(frames.shape[0])
            st2 = state
            for t in range(frames.shape[0]):
                f0 = time.perf_counter_ns()
                y2, st2 = step(params, st2, frames[t])
                jax.block_until_ready((y2, st2))
                dur = time.perf_counter_ns() - f0
                times[t] = dur / 1e3
                if tr is not None:
                    tr.complete("serve/frame", f0, dur, track="frames",
                                args={"frame": t})
                if reg is not None:
                    reg.histogram("serve/frames/wall_us").observe(
                        dur / 1e3)
            frame_us = times
    us_per_frame = dt / frames.shape[0] * 1e6
    if collect_frame_times:
        return jnp.stack(outs), st, us_per_frame, frame_us
    return jnp.stack(outs), st, us_per_frame
