"""Front-end router over N serve-engine replicas.

One engine replica is a fixed set of decode slots (possibly its own
mesh); pod-scale serving runs N of them behind a router that decides,
per request, which replica admits it. This module keeps the decision
layer modelless and testable on CPU:

* :func:`route` — assign a request trace to replicas under a policy.
  ``least_loaded`` is load-aware admission built directly on
  :func:`repro.serve.scheduler.simulate_admission`: for each candidate
  replica it replays the replica's already-assigned trace plus the new
  request and takes the projected makespan (``final_step``), weighted
  by the replica's per-step cost (the dryrun's roofline step time —
  heterogeneous replicas route proportionally slower). ``round_robin``
  is the baseline.
* :func:`simulate_replicas` — the trace-driven multi-replica dryrun
  core: route, replay each replica, merge per-request TTFT/latency into
  fleet-wide p50/p99 and SLO attainment (requests carrying
  ``Request.deadline_us``). ``launch/dryrun.py`` calls this with the
  roofline step time per decode cell.
* :class:`Router` — the executing front-end: partitions the trace and
  runs a real engine (``serve_continuous`` or ``serve_disaggregated``)
  per replica under one :class:`~.config.EngineConfig`. Greedy decoding
  makes per-request tokens independent of which replica ran them, so a
  routed run is token-for-token identical to one big single engine on
  the same trace — the parity bar tests/test_disagg.py holds it to.

RTMobile's framing applies here: the router is judged on per-request
deadline attainment (p99), not blended throughput.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Sequence

import numpy as np

from repro.obs import metrics as obs_metrics, trace as obs_trace

from .config import EngineConfig, resolve_config
from .scheduler import Request, simulate_admission

POLICIES = ("round_robin", "least_loaded")

__all__ = ["POLICIES", "Router", "RouterResult", "make_arrival_trace",
           "route", "simulate_replicas"]


def _step_times(step_time_us, n_replicas: int) -> list[float]:
    """Scalar -> uniform fleet; sequence -> per-replica cost model."""
    if isinstance(step_time_us, (int, float)):
        return [float(step_time_us)] * n_replicas
    times = [float(t) for t in step_time_us]
    if len(times) != n_replicas:
        raise ValueError(
            f"step_time_us has {len(times)} entries for "
            f"{n_replicas} replicas")
    return times


def route(requests: list[Request], n_replicas: int, *,
          policy: str = "least_loaded", n_slots: int = 4,
          step_time_us: float | Sequence[float] = 1.0
          ) -> list[list[Request]]:
    """Partition ``requests`` over ``n_replicas`` replica queues.

    ``round_robin``: arrival order, modulo. ``least_loaded``: each
    request goes to the replica whose projected completion time
    (simulated makespan x per-step cost) grows least when it takes the
    request — ties break to the lowest replica index, so the assignment
    is deterministic for a fixed trace.
    """
    if n_replicas < 1:
        raise ValueError("need at least one replica")
    if policy not in POLICIES:
        raise ValueError(f"unknown routing policy {policy!r}; "
                         f"one of {POLICIES}")
    ordered = sorted(requests, key=lambda r: (r.arrival, r.rid))
    out: list[list[Request]] = [[] for _ in range(n_replicas)]
    if policy == "round_robin":
        for i, r in enumerate(ordered):
            out[i % n_replicas].append(r)
        return out
    times = _step_times(step_time_us, n_replicas)
    # simulate_admission is pure host replay — cheap enough to re-run
    # per (request, candidate replica) at routing scale
    for r in ordered:
        best, best_cost = 0, None
        for i in range(n_replicas):
            sim = simulate_admission(n_slots, out[i] + [r])
            cost = sim["final_step"] * times[i]
            if best_cost is None or cost < best_cost:
                best, best_cost = i, cost
        out[best].append(r)
    return out


def simulate_replicas(requests: list[Request], n_replicas: int, *,
                      policy: str = "least_loaded", n_slots: int = 4,
                      step_time_us: float | Sequence[float] = 1.0
                      ) -> dict:
    """Trace-driven multi-replica dryrun: route, replay every replica
    through :func:`simulate_admission`, and merge the per-request SLO
    records into fleet-wide percentiles.

    Returns per-policy-comparable stats: ``ttft_us``/``latency_us``
    p50+p99, ``slo_attainment`` (None when no request carries a
    deadline), per-replica occupancy/load, and the raw per-replica
    stats for drill-down.
    """
    times = _step_times(step_time_us, n_replicas)
    assignment = route(requests, n_replicas, policy=policy,
                       n_slots=n_slots, step_time_us=times)
    per_replica, ttft, lat = [], [], []
    met = deadlines = 0
    for i, sub in enumerate(assignment):
        stats = simulate_admission(n_slots, sub, step_time_us=times[i])
        slo = stats["slo"]
        for rec in slo["per_request"].values():
            ttft.append(rec["ttft_us"])
            lat.append(rec["latency_us"])
            if rec["met"] is not None:
                deadlines += 1
                met += rec["met"]
        per_replica.append({
            "requests": stats["requests"],
            "occupancy": stats["occupancy"],
            "final_step": stats["final_step"],
            "step_time_us": times[i],
            "slo": {k: v for k, v in slo.items()
                    if k != "per_request"},
        })

    def pct(a, q):
        return round(float(np.percentile(a, q)), 3) if a else 0.0

    return {
        "policy": policy,
        "replicas": n_replicas,
        "slots_per_replica": n_slots,
        "requests": len(lat),
        "ttft_us": {"p50": pct(ttft, 50), "p99": pct(ttft, 99)},
        "latency_us": {"p50": pct(lat, 50), "p99": pct(lat, 99)},
        "deadlines": deadlines,
        "slo_attainment": (round(met / deadlines, 4)
                           if deadlines else None),
        "per_replica": per_replica,
    }


def make_arrival_trace(rng: np.random.Generator, n_requests: int, *,
                       vocab: int = 256, prompt_lo: int = 4,
                       prompt_hi: int = 24, new_lo: int = 8,
                       new_hi: int = 33, mean_gap_steps: float = 1.0,
                       deadline_slack: float | None = None,
                       step_time_us: float = 1.0) -> list[Request]:
    """A Poisson-arrival mixed-length trace for router dryruns.

    ``mean_gap_steps`` sets the arrival rate (exponential inter-arrival
    gaps in decode steps — smaller = heavier load). With
    ``deadline_slack`` each request carries
    ``deadline_us = slack * (max_new_tokens + 1) * step_time_us`` — a
    per-request realtime budget proportional to its own ideal service
    time, so attainment measures queueing/routing, not trace skew.
    """
    t = 0.0
    reqs = []
    for i in range(n_requests):
        t += float(rng.exponential(mean_gap_steps))
        plen = int(rng.integers(prompt_lo, prompt_hi))
        mnt = int(rng.integers(new_lo, new_hi))
        deadline = (deadline_slack * (mnt + 1) * step_time_us
                    if deadline_slack is not None else None)
        reqs.append(Request(
            rid=i, tokens=rng.integers(0, vocab, size=plen,
                                       dtype=np.int64).astype(np.int32),
            max_new_tokens=mnt, arrival=int(t), deadline_us=deadline))
    return reqs


@dataclasses.dataclass
class RouterResult:
    """Outcome of a routed multi-replica run."""

    tokens: dict[int, list[int]]      # rid -> generated tokens (merged)
    stats: dict                       # router + per-replica stats
    wall_s: float
    per_replica: list                 # the underlying ServeResults


class Router:
    """Executing front-end over N engine replicas.

    ``engine`` picks the per-replica engine: ``"continuous"``
    (``serve_continuous``) or ``"disagg"`` (``serve_disaggregated`` —
    prefill/decode tiers inside each replica). All replicas share one
    :class:`EngineConfig`. On this process the replicas run
    sequentially on the same device/mesh — the router's value here is
    the *assignment* (and its simulation); a deployment points each
    replica at its own mesh.
    """

    def __init__(self, n_replicas: int,
                 config: EngineConfig | None = None, *,
                 policy: str = "least_loaded",
                 step_time_us: float | Sequence[float] = 1.0,
                 engine: str = "continuous"):
        if policy not in POLICIES:
            raise ValueError(f"unknown routing policy {policy!r}; "
                             f"one of {POLICIES}")
        if engine not in ("continuous", "disagg"):
            raise ValueError(
                f"engine must be 'continuous' or 'disagg', got {engine!r}")
        self.n_replicas = n_replicas
        self.config = resolve_config(config, caller="Router")
        self.policy = policy
        self.step_time_us = _step_times(step_time_us, n_replicas)
        self.engine = engine

    def assign(self, requests: list[Request]) -> list[list[Request]]:
        return route(requests, self.n_replicas, policy=self.policy,
                     n_slots=self.config.n_slots,
                     step_time_us=self.step_time_us)

    def simulate(self, requests: list[Request]) -> dict:
        return simulate_replicas(requests, self.n_replicas,
                                 policy=self.policy,
                                 n_slots=self.config.n_slots,
                                 step_time_us=self.step_time_us)

    def serve(self, params, cfg, requests: list[Request], *,
              mesh=None, policy=None, rng=None) -> RouterResult:
        """Route, then run the engine per replica; merge results.

        Per-request tokens are identical to a single engine serving the
        whole trace (greedy decode is replica-independent)."""
        from .disagg import serve_disaggregated
        from .engine import serve_continuous

        engine_fn = (serve_disaggregated if self.engine == "disagg"
                     else serve_continuous)
        assignment = self.assign(requests)
        reg = obs_metrics.get()
        tr = obs_trace.get()
        per: list = []
        t0 = time.perf_counter()
        for ridx, sub in enumerate(assignment):
            if reg is not None:
                reg.gauge(f"serve/router/replica{ridx}/load").set(
                    len(sub))
            t_r = time.perf_counter_ns()
            res = engine_fn(params, cfg, sub, self.config, mesh=mesh,
                            policy=policy, rng=rng)
            if tr is not None:
                tr.complete("serve/router/replica", t_r,
                            time.perf_counter_ns() - t_r,
                            track="router",
                            args={"replica": ridx,
                                  "requests": len(sub),
                                  "tokens": res.stats[
                                      "generated_tokens"]})
            per.append(res)
        wall = time.perf_counter() - t0
        tokens: dict[int, list[int]] = {}
        for res in per:
            tokens.update(res.tokens)
        stats = {
            "policy": self.policy,
            "engine": self.engine,
            "replicas": self.n_replicas,
            "requests": sum(r.stats["requests"] for r in per),
            "generated_tokens": sum(
                r.stats["generated_tokens"] for r in per),
            "replica_requests": [len(a) for a in assignment],
            "per_replica": [r.stats for r in per],
        }
        stats["tokens_per_sec"] = round(
            stats["generated_tokens"] / wall, 3) if wall > 0 else 0.0
        return RouterResult(tokens, stats, wall, per)
