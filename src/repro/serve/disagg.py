"""Disaggregated serving: a prefill tier feeding a decode tier through
explicit page handoffs.

Continuous batching interleaves two workloads with opposite shapes on
one engine: prefill is a bursty, throughput-bound batch matmul over a
whole prompt; decode is a latency-bound single-token step that wants to
stay hot and uninterrupted (the paper keeps its recurrent step resident
on-chip for exactly this reason). This module splits them:

* :class:`PrefillTier` — throughput-optimized: pow2 prompt-bucketed
  full prefill (O(log max_len) compiled variants) and trie-aware
  partial prefill (``prefill_partial`` against pages gathered from the
  decode tier's pool). It owns its own :class:`~.engine._Runner`, so on
  a real deployment the tiers can live on different meshes.
* :class:`DecodeTier` — latency-optimized: a fixed set of decode slots
  over its own :class:`~.paging.PagePool` and the once-compiled
  vector-position paged decode step (optionally the Pallas kernel).
* :class:`PageHandoff` — the explicit object crossing the boundary: one
  completed prefill (prompt, sampled first token, single-request KV)
  that :meth:`DecodeTier.accept` remaps into the decode pool —
  copy-on-write first when the suffix starts inside a trie-shared page,
  then page ``ensure`` + scatter, then trie registration — so refcount
  conservation holds under prefix sharing (the ``PagePool.check()``
  oracle is fuzzed over exactly this event sequence in
  tests/test_paging.py).

``serve_disaggregated`` orchestrates both tiers over one
:class:`~.scheduler.SlotScheduler` (admission is by the decode pool's
free pages, as always) and is token-for-token identical to
``serve_continuous`` on the same trace: both run the same bucketed
prefill, the same paged decode step, and split the rng in the same
order. With :mod:`repro.obs` enabled, the run emits per-tier queue-wait
histograms, a ``serve/handoff`` span per handoff (with its page count)
and decode-tier occupancy gauges.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig
from repro.models import lm as LM
from repro.obs import metrics as obs_metrics, trace as obs_trace

from .config import EngineConfig, resolve_config
from .engine import (
    ServeResult, _Runner, _gather_ctx, _resolve_mesh, _sampler, bucket_len,
)
from .paging import PagePool, SharedInfo, pages_for
from .scheduler import (
    Request, SlotScheduler, copy_page_cache, evict_slot_state,
    fit_cache_len, insert_paged_cache, insert_paged_span,
)

PyTree = Any

__all__ = ["PageHandoff", "PrefillTier", "DecodeTier",
           "serve_disaggregated"]


@dataclasses.dataclass
class PageHandoff:
    """One completed prefill crossing the tier boundary.

    ``req_cache`` is the single-request contiguous KV/state the prefill
    produced (bucket-padded time extent); ``shared`` is the decode
    pool's trie match recorded at admission (None / zero pages when the
    prompt was prefilled whole). The handoff is inert data — nothing is
    mapped until :meth:`DecodeTier.accept` remaps it into the pool.
    """

    rid: int
    slot: int                      # decode-tier slot reserved at admission
    tokens: np.ndarray             # full prompt (trie registration key)
    prompt_len: int
    first_token: int               # sampled off the prefill logits
    req_cache: PyTree
    shared: SharedInfo | None
    created_ns: int                # prefill completion (queue-wait clock)

    @property
    def suffix_start(self) -> int:
        if self.shared is not None and self.shared.shared_pages > 0:
            return self.shared.suffix_start
        return 0


class PrefillTier:
    """Throughput tier: bucketed full prefill + trie-aware partial
    prefill, reusing the engine's jitted ``prefill``/``prefill_partial``
    executables. Produces :class:`PageHandoff` objects; never touches
    the decode pool."""

    def __init__(self, params, cfg: ModelConfig, config: EngineConfig,
                 *, mesh=None, policy=None):
        self.cfg = cfg
        self.runner = _Runner(params, cfg, mesh, policy)
        bucket = (config.bucket_prompts
                  if config.bucket_prompts is not None else True)
        self.bucket = bucket and cfg.mixer in ("attn", "mla")
        self.prefill_tokens = 0

    def run(self, req: Request, slot: int, sample, key, *,
            shared: SharedInfo | None = None,
            ctx: PyTree | None = None) -> PageHandoff:
        """Prefill one admitted request (suffix-only on a trie match,
        against ``ctx`` gathered from the decode tier) and sample its
        first token. Returns the handoff for the decode tier."""
        tokens = np.asarray(req.tokens)
        plen = req.prompt_len
        if shared is not None and shared.shared_pages > 0:
            sstart = shared.suffix_start
            s_real = plen - sstart
            suffix = tokens[sstart:]
            if self.bucket:
                suffix = np.pad(suffix,
                                [(0, bucket_len(s_real) - s_real)])
            logits, req_cache = self.runner.prefill_partial(
                jnp.asarray(suffix)[None], ctx, start=sstart,
                last_pos=s_real - 1)
            self.prefill_tokens += int(suffix.shape[0])
        elif self.bucket:
            pad = bucket_len(plen) - plen
            padded = np.pad(tokens,
                            [(0, pad)] + [(0, 0)] * (tokens.ndim - 1))
            logits, req_cache = self.runner.prefill(
                jnp.asarray(padded)[None], last_pos=plen - 1)
            self.prefill_tokens += int(padded.shape[0])
        else:
            logits, req_cache = self.runner.prefill(
                jnp.asarray(tokens)[None])
            self.prefill_tokens += plen
        first = int(np.asarray(sample(logits, key)).reshape(-1)[0])
        return PageHandoff(rid=req.rid, slot=slot, tokens=tokens,
                           prompt_len=plen, first_token=first,
                           req_cache=req_cache, shared=shared,
                           created_ns=time.perf_counter_ns())


class DecodeTier:
    """Latency tier: fixed decode slots over a private
    :class:`~.paging.PagePool`, accepting handoffs by page remap and
    stepping every active slot through the once-compiled vector-pos
    paged decode step."""

    def __init__(self, params, cfg: ModelConfig, config: EngineConfig,
                 cache_len: int, *, mesh=None, policy=None):
        self.cfg = cfg
        self.config = config
        self.runner = _Runner(params, cfg, mesh, policy)
        self.prefix = config.prefix_cache and cfg.mixer in ("attn", "mla")
        max_pages = pages_for(cache_len, config.page_size)
        n_pool = (config.n_slots * max_pages
                  if config.pool_pages is None else config.pool_pages)
        self.pool = PagePool(config.page_size, n_pool, config.n_slots,
                             max_pages, prefix_cache=self.prefix)
        self.sched = SlotScheduler(config.n_slots, pool=self.pool)
        self.cache = self.runner.place_cache(
            LM.init_paged_cache(cfg, self.pool.n_pages, config.page_size,
                                config.n_slots, jnp.dtype(cfg.dtype)),
            paged=True)
        self.cur = jnp.zeros((config.n_slots, 1), jnp.int32)
        self._table_host = None
        self._table_placed = None
        self.handoffs = 0
        self.handoff_pages = 0

    def shared_ctx(self, slot: int):
        """(SharedInfo, gathered ctx) for a trie-matched admission —
        the prefill tier's partial-prefill input. The page row is
        scratch-padded to a pow2 count so compiled partial-prefill
        variants stay O(log max_pages). (None, None) on no match."""
        info = self.pool.shared_info(slot)
        if info is None or info.shared_pages == 0:
            return None, None
        sp = info.shared_pages
        n_pad = 1 << max(sp - 1, 0).bit_length()
        ctx_row = np.concatenate([
            self.pool.slot_row(slot)[:sp],
            np.full(n_pad - sp, self.pool.scratch_page, np.int32)])
        return info, _gather_ctx(self.cache, ctx_row)

    def accept(self, h: PageHandoff) -> bool:
        """Remap a handoff into the decode pool: CoW the divergence
        page when the suffix starts inside a shared page, ``ensure`` the
        prompt's pages, scatter/insert the prefilled KV, then register
        the prompt in the trie. Returns False when the request finished
        at prefill (``max_new_tokens == 1`` — nothing is mapped)."""
        t_acc = time.perf_counter_ns()
        pool, runner = self.pool, self.runner
        slot, plen = h.slot, h.prompt_len
        alive = self.sched.started(slot, h.first_token)
        n_pages = 0
        if alive:
            shared = h.shared is not None and h.shared.shared_pages > 0
            if shared:
                # divergence inside a shared page: private copy BEFORE
                # the suffix write lands (refcount moves src -> dst)
                cow = pool.cow_if_needed(slot)
                if cow is not None:
                    self.cache = copy_page_cache(self.cache, *cow)
                pool.ensure(slot, plen)
                self.cache = insert_paged_span(
                    self.cache, runner.place_slot_cache(h.req_cache),
                    pool.slot_row(slot), h.shared.suffix_start,
                    plen - h.shared.suffix_start, slot)
            else:
                pool.ensure(slot, plen)
                phys = list(pool.slot_pages(slot))
                # pow2 scratch padding keeps the jitted insert variants
                # O(log max_pages), as in the single engine
                n_pad = 1 << max(len(phys) - 1, 0).bit_length()
                phys += [pool.scratch_page] * (n_pad - len(phys))
                req_cache = fit_cache_len(
                    h.req_cache, len(phys) * self.config.page_size)
                self.cache = insert_paged_cache(
                    self.cache, runner.place_slot_cache(req_cache),
                    phys, slot)
            if self.prefix:
                pool.register_prefix(slot, h.tokens)
            self.cur = self.cur.at[slot, 0].set(h.first_token)
            n_pages = len(pool.slot_pages(slot))
        self.handoffs += 1
        self.handoff_pages += n_pages
        t_end = time.perf_counter_ns()
        tr = obs_trace.get()
        if tr is not None:
            tr.complete("serve/handoff", h.created_ns,
                        t_end - h.created_ns, track="handoff",
                        args={"rid": h.rid, "slot": slot,
                              "pages": n_pages,
                              "shared_pages": (h.shared.shared_pages
                                               if h.shared else 0)})
        reg = obs_metrics.get()
        if reg is not None:
            reg.counter("serve/handoff/count").inc()
            reg.counter("serve/handoff/pages").inc(n_pages)
            # decode-tier queue wait: prefill completion -> pages mapped
            reg.histogram("serve/disagg/handoff_queue_us").observe(
                (t_acc - h.created_ns) / 1e3)
        return alive

    def step(self, sample, key) -> tuple[list[int], int]:
        """One paged decode step over every active slot. Returns
        (freed slots, active count); sets ``runner.last_cold``."""
        sched, pool, runner = self.sched, self.pool, self.runner
        active = sched.active_mask()
        pos_host = sched.positions()
        pos = runner.place_pos(jnp.asarray(pos_host))
        for i in np.flatnonzero(active):
            pool.ensure(int(i), int(pos_host[i]) + 1)
        pool.tick()
        fresh = pool.device_table()
        if fresh is not self._table_host:
            self._table_host = fresh
            self._table_placed = runner.place_table(fresh)
        lg, self.cache = runner.step_paged(
            self.cache, runner.place_tokens(self.cur), pos,
            self._table_placed, use_kernel=self.config.use_kernel)
        nxt = sample(lg[:, -1], key)
        nxt_host = np.asarray(nxt)          # blocks: true step latency
        freed = sched.advance(nxt_host)
        for slot in freed:
            # pages returned inside the scheduler; SSM/conv state needs
            # the device-side zero
            self.cache = evict_slot_state(self.cache, slot)
        self.cur = nxt[:, None].astype(jnp.int32)
        reg = obs_metrics.get()
        if reg is not None:
            reg.gauge("serve/tier/decode_active").set(int(active.sum()))
        return freed, int(active.sum())


def serve_disaggregated(params, cfg: ModelConfig,
                        requests: list[Request],
                        config: EngineConfig | None = None, *,
                        mesh=None, policy=None,
                        rng: jax.Array | None = None) -> ServeResult:
    """Serve ``requests`` through split prefill/decode tiers.

    Requires ``config.paged=True`` — the handoff IS a page remap into
    the decode tier's pool. Tokens are identical to
    ``serve_continuous`` with the same config on the same trace (same
    bucketed prefill, same paged step, same rng split order), which the
    bench lane asserts before emitting its gated row. The old loose
    kwargs work through the same one-release deprecation shim as
    ``serve_continuous``.
    """
    if cfg.n_codebooks:
        raise NotImplementedError(
            "serve_disaggregated drives single-stream token ids; "
            "codebook models go through generate()")
    config = resolve_config(config, caller="serve_disaggregated")
    if not config.paged:
        raise ValueError(
            "serve_disaggregated requires config.paged=True (the "
            "prefill->decode handoff is a page remap)")
    if not requests:
        stats = SlotScheduler(config.n_slots).stats()
        stats.update(cache_len=0, tokens_per_sec=0.0, paged=True,
                     disagg=True, bucketed_prefill=False,
                     prefix_cache=False, prefill_tokens=0,
                     handoffs=0, handoff_pages=0,
                     compile_time_s=0.0, steady_tokens_per_sec=0.0,
                     sharded=_resolve_mesh(mesh) is not None)
        stats["paging"] = PagePool(
            config.page_size,
            1 if config.pool_pages is None else config.pool_pages,
            config.n_slots, 1).summary()
        stats["page_stalls"] = 0
        return ServeResult({}, stats, 0.0)
    cache_len = config.cache_len or max(
        r.prompt_len + r.max_new_tokens for r in requests)
    short = [r for r in requests
             if r.prompt_len + r.max_new_tokens > cache_len]
    if short:
        raise ValueError(
            f"cache_len={cache_len} cannot hold request(s) "
            f"{[r.rid for r in short]}")

    prefill_tier = PrefillTier(params, cfg, config, mesh=mesh,
                               policy=policy)
    decode_tier = DecodeTier(params, cfg, config, cache_len, mesh=mesh,
                             policy=policy)
    sched = decode_tier.sched
    for r in requests:
        sched.submit(r)
    sample = _sampler(cfg, config.temperature)
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    prefix = decode_tier.prefix

    def _admissions():
        # one-at-a-time under the prefix cache so each handoff's trie
        # registration is visible to the very next admission (same
        # protocol as serve_continuous)
        if not prefix:
            yield from sched.admit()
            return
        while True:
            batch = sched.admit(limit=1)
            if not batch:
                return
            yield batch[0]

    reg = obs_metrics.get()
    eligible_ns: dict[int, int] = {}
    compile_ns = steady_ns = steady_tokens = 0

    t0 = time.perf_counter()
    while sched.has_work():
        if reg is not None:
            now_ns = time.perf_counter_ns()
            for rid in sched.arrived_pending():
                eligible_ns.setdefault(rid, now_ns)
            reg.gauge("serve/tier/prefill_backlog").set(
                len(sched.arrived_pending()))
        for slot, req in _admissions():
            rng, k = jax.random.split(rng)
            t_pf = time.perf_counter_ns()
            if reg is not None:
                # prefill-tier queue wait: eligible -> prefill start
                reg.histogram("serve/disagg/prefill_queue_us").observe(
                    (t_pf - eligible_ns.get(req.rid, t_pf)) / 1e3)
            info, ctx = (decode_tier.shared_ctx(slot) if prefix
                         else (None, None))
            h = prefill_tier.run(req, slot, sample, k, shared=info,
                                 ctx=ctx)
            if prefill_tier.runner.last_cold:
                compile_ns += time.perf_counter_ns() - t_pf
            decode_tier.accept(h)
        if not sched.active_mask().any():
            sched.idle_tick()
            continue
        rng, k = jax.random.split(rng)
        t_st = time.perf_counter_ns()
        _, n_active = decode_tier.step(sample, k)
        t_en = time.perf_counter_ns()
        if decode_tier.runner.last_cold:
            compile_ns += t_en - t_st
        else:
            steady_ns += t_en - t_st
            steady_tokens += n_active
    jax.block_until_ready(decode_tier.cache)
    wall = time.perf_counter() - t0

    stats = sched.stats()
    stats["cache_len"] = cache_len
    stats["paged"] = True
    stats["disagg"] = True
    stats["bucketed_prefill"] = prefill_tier.bucket
    stats["prefix_cache"] = prefix
    stats["prefill_tokens"] = prefill_tier.prefill_tokens
    stats["handoffs"] = decode_tier.handoffs
    stats["handoff_pages"] = decode_tier.handoff_pages
    stats["tokens_per_sec"] = round(
        stats["generated_tokens"] / wall, 3) if wall > 0 else 0.0
    stats["compile_time_s"] = round(compile_ns / 1e9, 6)
    stats["steady_tokens_per_sec"] = round(
        steady_tokens / (steady_ns / 1e9), 3) if steady_ns > 0 else 0.0
    stats["sharded"] = decode_tier.runner.mesh is not None
    return ServeResult(sched.results, stats, wall)
