"""Cycle model of the CSB-Engine (paper §6.3.2, Fig. 12).

A PEGroup of P x Q PEs processes an (m x n) kernel partition in
ceil(m/P) * ceil(n/Q) passes (one MAC per PE per cycle). Within one block
iteration all K x L PEGroups run in lockstep, so the iteration takes the
*maximum* group cycle count — utilization is true MACs over issued
PE-cycles. Workload sharing (engine.schedule) shrinks that maximum; this
model reproduces the paper's 42% -> ~72% -> ~94% utilization ladder.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.csb_format import CSBMatrix
from .schedule import Schedule, greedy_schedule, no_sharing_schedule


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    K: int = 4        # PEGroup rows
    L: int = 4        # PEGroup cols
    P: int = 4        # PEs per group (rows)
    Q: int = 4
    freq_mhz: float = 200.0

    @property
    def pes(self) -> int:
        return self.K * self.L * self.P * self.Q


@dataclasses.dataclass
class SimResult:
    cycles: int
    true_macs: int
    issued_macs: int
    efficiency: float
    latency_us: float
    mode: str


def make_schedule(csb: CSBMatrix, ecfg: EngineConfig,
                  sharing: str = "2d", solver: str = "greedy") -> Schedule:
    m, n = csb.m.astype(np.int64), csb.n.astype(np.int64)
    if sharing == "none":
        return no_sharing_schedule(m, n, ecfg.K, ecfg.L, ecfg.P, ecfg.Q)
    if solver == "smt":
        from .schedule import smt_schedule
        return smt_schedule(m, n, ecfg.K, ecfg.L, ecfg.P, ecfg.Q,
                            mode=sharing)
    return greedy_schedule(m, n, ecfg.K, ecfg.L, ecfg.P, ecfg.Q,
                           mode=sharing)


def simulate_matrix(csb: CSBMatrix, ecfg: EngineConfig,
                    sharing: str = "2d",
                    schedule: Schedule | None = None) -> SimResult:
    """Simulate one CSB-MVM (the whole sparse weight matrix x vector)."""
    if schedule is None:
        schedule = make_schedule(csb, ecfg, sharing)
    total_cycles = schedule.total_cycles
    true = int((csb.m.astype(np.int64) * csb.n).sum())
    issued = total_cycles * ecfg.pes
    eff = true / issued if issued else 0.0
    lat = total_cycles / (ecfg.freq_mhz * 1e6) * 1e6
    return SimResult(total_cycles, true, issued, eff, lat, schedule.mode)


def simulate_model_layer(
    csb_list: list[CSBMatrix], ecfg: EngineConfig, sharing: str = "2d",
) -> SimResult:
    """All MVMs of one RNN layer (e.g. 8 matrices for an LSTM)."""
    res = [simulate_matrix(c, ecfg, sharing) for c in csb_list]
    cycles = sum(r.cycles for r in res)
    true = sum(r.true_macs for r in res)
    issued = sum(r.issued_macs for r in res)
    eff = true / issued if issued else 0.0
    lat = cycles / (ecfg.freq_mhz * 1e6) * 1e6
    return SimResult(cycles, true, issued, eff, lat, sharing)


def dense_latency_us(shape: tuple[int, int], ecfg: EngineConfig) -> float:
    """Reference: unpruned dense MVM on the same PE grid."""
    out_dim, in_dim = shape
    macs = out_dim * in_dim
    cycles = -(-macs // ecfg.pes)
    return cycles / (ecfg.freq_mhz * 1e6) * 1e6
