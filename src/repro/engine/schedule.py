"""Workload-balancing compilation for the CSB-Engine (paper §5.2).

A PEGroup of P x Q PEs processes an (m x n) kernel in
``ceil(m/P) * ceil(n/Q)`` multi-passes — small kernels waste PE-cycles on
pass granularity, and kernel-size variance across a K x L block iteration
leaves whole groups idle (Fig. 7b). Two schedulers rebalance each
iteration:

``smt_schedule``    — the paper's Algorithm 2: partition variables
    (m', n', dm_h, dn_h, dm_v, dn_v) per PEGroup constrained by CLP1-CLP7
    and solved with Z3, growing ``margin`` by P*Q until SAT.

``greedy_schedule`` — beyond-paper production path: torus-neighbour
    donation of PE-aligned cycle quanta (the same sharing paths the
    hardware has: right->left horizontal, down->up vertical), ~1000x
    faster than Z3 with near-identical balance.

Both return per-iteration per-group CYCLE counts; true MAC totals live in
the CSB matrix itself. The simulator turns these into utilization.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .isa import MicroInst


@dataclasses.dataclass
class Schedule:
    """Per-iteration per-group cycle counts after balancing."""

    iter_cycles: list[np.ndarray]          # each (K, L) int cycles
    micro: list[MicroInst]
    mode: str                              # none | vertical | horizontal | 2d
    solver_rounds: int = 0

    @property
    def total_cycles(self) -> int:
        return int(sum(int(c.max()) for c in self.iter_cycles))


def _iter_tiles(m: np.ndarray, n: np.ndarray, k: int, l: int):
    """Yield (i0, j0, mt, nt) — K x L tiles of the block grid (blocks are
    mapped row-major, paper §4.3.1)."""
    br, bc = m.shape
    for i0 in range(0, br, k):
        for j0 in range(0, bc, l):
            mt = np.zeros((k, l), np.int64)
            nt = np.zeros((k, l), np.int64)
            ms = m[i0: i0 + k, j0: j0 + l]
            ns = n[i0: i0 + k, j0: j0 + l]
            mt[: ms.shape[0], : ms.shape[1]] = ms
            nt[: ns.shape[0], : ns.shape[1]] = ns
            yield i0, j0, mt, nt


def _block_cycles(mt, nt, P, Q) -> np.ndarray:
    """Cycles a PEGroup spends on a kernel. Blocks stream back-to-back
    through the PE pipeline (the NeuronAccumBuffer lets the next pass
    start while the previous accumulates — paper §4.3.1 measures
    *pipeline* utilization), so partial passes pack: ceil(m*n / P*Q)."""
    return np.ceil(mt * nt / (P * Q)).astype(np.int64)


def no_sharing_schedule(m, n, K, L, P, Q) -> Schedule:
    iters = []
    micro = []
    for i0, j0, mt, nt in _iter_tiles(np.asarray(m), np.asarray(n), K, L):
        iters.append(_block_cycles(mt, nt, P, Q))
        for k in range(K):
            for l in range(L):
                if mt[k, l] and nt[k, l]:
                    micro.append(MicroInst((k, l), "local",
                                           int(mt[k, l]), int(nt[k, l]),
                                           (i0 + k, j0 + l)))
    return Schedule(iters, micro, "none")


def _neighbours(k, l, K, L, mode):
    out = []
    if mode in ("horizontal", "2d"):
        out.append((k, (l - 1) % L))
    if mode in ("vertical", "2d"):
        out.append(((k - 1) % K, l))
    return out


def greedy_schedule(m, n, K, L, P, Q, mode: str = "2d",
                    rounds: int = 8) -> Schedule:
    """Donate PE-aligned cycle quanta to torus neighbours until balanced."""
    assert mode in ("vertical", "horizontal", "2d")
    iters = []
    micro: list[MicroInst] = []
    for i0, j0, mt, nt in _iter_tiles(np.asarray(m), np.asarray(n), K, L):
        cyc = _block_cycles(mt, nt, P, Q)
        for _ in range(rounds):
            moved = False
            order = np.dstack(np.unravel_index(
                np.argsort(cyc, axis=None)[::-1], cyc.shape))[0]
            for k, l in order:
                # waterfill the donor against its neighbour set: donors
                # may push receivers above the mean (chains resolve over
                # rounds — physically, a receiver's own block can be
                # shared onward along the opposite torus direction).
                for (tk, tl) in sorted(_neighbours(k, l, K, L, mode),
                                       key=lambda t: cyc[t]):
                    give = (cyc[k, l] - cyc[tk, tl]) // 2
                    if give > 0:
                        cyc[k, l] -= give
                        cyc[tk, tl] += give
                        moved = True
                        micro.append(MicroInst(
                            (tk, tl),
                            "horizontal" if tk == k else "vertical",
                            int(give) * P, Q, (i0 + k, j0 + l)))
            if not moved:
                break
        iters.append(cyc)
        for k in range(K):
            for l in range(L):
                if mt[k, l] and nt[k, l]:
                    micro.append(MicroInst((k, l), "local",
                                           int(mt[k, l]), int(nt[k, l]),
                                           (i0 + k, j0 + l)))
    return Schedule(iters, micro, mode)


def smt_schedule(m, n, K, L, P, Q, mode: str = "2d",
                 max_rounds: int = 64) -> Schedule:
    """Paper Algorithm 2 with Z3 (CLP1-CLP7)."""
    import z3

    assert mode in ("vertical", "horizontal", "2d")
    iters = []
    micro: list[MicroInst] = []
    total_rounds = 0
    for i0, j0, mt, nt in _iter_tiles(np.asarray(m), np.asarray(n), K, L):
        avg = float((mt * nt).sum()) / (K * L)
        margin = 0
        rounds = 0
        model = None
        mp = np_ = dmh = dnh = dmv = dnv = None
        while model is None and rounds < max_rounds:
            rounds += 1
            s = z3.Solver()
            s.set("timeout", 5000)
            mp, np_, dmh, dnh, dmv, dnv = {}, {}, {}, {}, {}, {}
            for k in range(K):
                for l in range(L):
                    mk, nk = int(mt[k, l]), int(nt[k, l])
                    mp[k, l] = z3.Int(f"mp_{k}_{l}")
                    np_[k, l] = z3.Int(f"np_{k}_{l}")
                    dmh[k, l] = z3.Int(f"dmh_{k}_{l}")
                    dnh[k, l] = z3.Int(f"dnh_{k}_{l}")
                    dmv[k, l] = z3.Int(f"dmv_{k}_{l}")
                    dnv[k, l] = z3.Int(f"dnv_{k}_{l}")
                    # CLP1 / CLP2 feasible region
                    s.add(dmh[k, l] >= 0, dmh[k, l] <= mk)
                    s.add(dnh[k, l] >= 0, dnh[k, l] <= nk)
                    s.add(dmv[k, l] >= 0, dmv[k, l] <= mk // 2)
                    s.add(dnv[k, l] >= 0, dnv[k, l] <= nk)
                    if mode == "horizontal":
                        s.add(dmv[k, l] == 0, dnv[k, l] == 0)
                    if mode == "vertical":
                        s.add(dmh[k, l] == 0, dnh[k, l] == 0)
                    # CLP3 v CLP4 regular partitions (Fig. 9a)
                    clp3 = z3.And(dmh[k, l] == mk,
                                  dnv[k, l] + dnh[k, l] == nk)
                    clp4 = z3.And(dnv[k, l] == nk,
                                  dmh[k, l] + dmv[k, l] == mk)
                    zero = z3.And(dmh[k, l] == 0, dnh[k, l] == 0,
                                  dmv[k, l] == 0, dnv[k, l] == 0)
                    s.add(z3.Or(clp3, clp4, zero))
                    # CLP5 definitions
                    s.add(mp[k, l] == mk - dmv[k, l])
                    s.add(np_[k, l] == nk - dnh[k, l])
                    # CLP6 PE-aligned shared partitions
                    s.add(dmv[k, l] % P == 0)
                    s.add(dnh[k, l] % Q == 0)
            for k in range(K):
                for l in range(L):
                    # CLP7: workload within margin of avg (torus neighbours)
                    w = (mp[k, l] * np_[k, l]
                         + dmh[k, (l + 1) % L] * dnh[k, (l + 1) % L]
                         + dmv[(k + 1) % K, l] * dnv[(k + 1) % K, l])
                    s.add(w - int(avg) <= margin)
            if s.check() == z3.sat:
                model = s.model()
            else:
                margin += P * Q
        total_rounds += rounds
        cyc = np.zeros((K, L), np.int64)
        for k in range(K):
            for l in range(L):
                if model is not None:
                    gm = model[mp[k, l]].as_long()
                    gn = model[np_[k, l]].as_long()
                    hk = model[dmh[k, (l + 1) % L]].as_long()
                    hn = model[dnh[k, (l + 1) % L]].as_long()
                    vk = model[dmv[(k + 1) % K, l]].as_long()
                    vn = model[dnv[(k + 1) % K, l]].as_long()
                else:  # timeout fallback: unbalanced
                    gm, gn = int(mt[k, l]), int(nt[k, l])
                    hk = hn = vk = vn = 0
                cyc[k, l] = int(np.ceil(
                    (gm * gn + hk * hn + vk * vn) / (P * Q)))
                if gm * gn:
                    micro.append(MicroInst((k, l), "local", gm, gn,
                                           (i0 + k, j0 + l)))
                if hk * hn:
                    micro.append(MicroInst((k, l), "horizontal", hk, hn,
                                           (i0 + k, (j0 + l + 1))))
                if vk * vn:
                    micro.append(MicroInst((k, l), "vertical", vk, vn,
                                           ((i0 + k + 1), j0 + l)))
        iters.append(cyc)
    return Schedule(iters, micro, mode, solver_rounds=total_rounds)
