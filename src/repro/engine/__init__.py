"""repro.engine — the paper's architecture-compilation co-design layer:
VLIW macro compilation, SMT/greedy workload balancing, cycle simulation."""
from .isa import MacroProgram, MicroInst, compile_macro
from .schedule import (
    Schedule, greedy_schedule, no_sharing_schedule, smt_schedule,
)
from .simulator import (
    EngineConfig, SimResult, dense_latency_us, make_schedule,
    simulate_matrix, simulate_model_layer,
)

__all__ = [
    "MacroProgram", "MicroInst", "compile_macro",
    "Schedule", "greedy_schedule", "no_sharing_schedule", "smt_schedule",
    "EngineConfig", "SimResult", "dense_latency_us", "make_schedule",
    "simulate_matrix", "simulate_model_layer",
]
