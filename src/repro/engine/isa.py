"""Instruction set + macro-instruction compilation (paper §5.1).

The RNN dataflow architecture executes VLIW words whose sections drive
the operation units of Fig. 5/8: LoadUnit, CSB-Engine (MVM), two adders,
sigmoid, tanh, two multipliers, StoreUnit. ``compile_macro`` list-schedules
a cell's dataflow DAG (repro.cells) onto those units with the ASAP
strategy — the schedule length is what the latency model uses, and the
occupancy table reproduces the paper's claim that throughput is bounded
by the CSB-Engine section.
"""
from __future__ import annotations

import dataclasses

from repro.cells.dataflow import CellGraph

# op kind -> hardware unit pools (paper Fig. 8). relu rides the
# activation unit (Li-GRU extension); one_minus is an adder op.
UNIT_POOLS: dict[str, tuple[str, ...]] = {
    "mvm": ("CSB-Engine",),
    "add": ("Sum1", "Sum2"),
    "bias": ("Sum1", "Sum2"),
    "one_minus": ("Sum1", "Sum2"),
    "mul": ("Mult1", "Mult2"),
    "sigmoid": ("Sigmoid",),
    "relu": ("Sigmoid",),
    "tanh": ("Tanh",),
}

ALL_UNITS = ("LoadUnit", "CSB-Engine", "Sum1", "Sum2", "Sigmoid",
             "Tanh", "Mult1", "Mult2", "StoreUnit")


@dataclasses.dataclass(frozen=True)
class MacroSlot:
    unit: str
    op: str               # op name in the cell graph
    count: int            # workload elements (Count operand)


@dataclasses.dataclass
class MacroProgram:
    """One VLIW word per time slot; a slot maps unit -> MacroSlot."""

    words: list[dict[str, MacroSlot]]
    graph_name: str

    @property
    def length(self) -> int:
        return len(self.words)

    def occupancy(self) -> dict[str, float]:
        occ = {u: 0 for u in ALL_UNITS}
        for w in self.words:
            for u in w:
                occ[u] += 1
        n = max(len(self.words), 1)
        return {u: c / n for u, c in occ.items()}


def compile_macro(graph: CellGraph) -> MacroProgram:
    """ASAP list scheduling of the cell DAG onto the unit pools."""
    # dependency levels
    level: dict[str, int] = {}
    for op in graph.ops:
        if op.kind == "input":
            level[op.name] = -1
            continue
    scheduled: dict[str, int] = {}
    words: list[dict[str, MacroSlot]] = []

    def ready(op) -> bool:
        return all(
            (i in scheduled) or graph.op(i).kind == "input"
            for i in op.inputs)

    def dep_slot(op) -> int:
        slots = [-1]
        for i in op.inputs:
            if i in scheduled:
                slots.append(scheduled[i])
        return max(slots)

    remaining = [op for op in graph.ops if op.kind != "input"]
    t = 0
    guard = 0
    while remaining:
        guard += 1
        if guard > 10000:  # pragma: no cover
            raise RuntimeError("scheduling did not converge")
        while len(words) <= t:
            words.append({})
        used = set(words[t])
        placed = []
        usage: dict[str, int] = {}
        for w in words:
            for u in w:
                usage[u] = usage.get(u, 0) + 1
        for op in remaining:
            if not ready(op) or dep_slot(op) >= t:
                continue
            pool = UNIT_POOLS[op.kind]
            free = [u for u in pool if u not in used]
            # least-used unit in the pool: balances Sum1/Sum2, Mult1/Mult2
            unit = min(free, key=lambda u: usage.get(u, 0), default=None)
            if unit is None:
                continue
            count = op.shape[0] if op.shape else graph.hidden_dim
            words[t][unit] = MacroSlot(unit, op.name, int(count))
            used.add(unit)
            scheduled[op.name] = t
            placed.append(op)
        for op in placed:
            remaining.remove(op)
        t += 1
    return MacroProgram(words=[w for w in words if w],
                        graph_name=graph.name)


@dataclasses.dataclass(frozen=True)
class MicroInst:
    """CSB-Engine micro-instruction (paper Fig. 9): one workload partition
    executed by one PEGroup."""

    group: tuple[int, int]        # (k, l)
    sharing: str                  # local | horizontal | vertical
    trip_m: int
    trip_n: int
    block: tuple[int, int]        # source block (i, j) in the weight grid
