"""Optimizers as (init, update) pairs of pure functions.

``update(grads, state, params, lr) -> (new_params, new_state)``.

AdamW keeps fp32 moments; Adafactor keeps a factored second moment
(row/col statistics) so optimizer memory is ~O(sqrt) of AdamW — the
default for the 405B-class dry-run cells (see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any
F32 = jnp.float32


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[..., tuple[PyTree, PyTree]]
    name: str = "opt"


@dataclasses.dataclass
class OptState:
    inner: PyTree
    step: jax.Array

    def tree_flatten(self):
        return (self.inner, self.step), ()

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(inner=leaves[0], step=leaves[1])


jax.tree_util.register_pytree_node(
    OptState, OptState.tree_flatten, OptState.tree_unflatten)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(F32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(F32) * scale).astype(g.dtype),
                        grads)


# ---------------------------------------------------------------------------
# SGD + momentum
# ---------------------------------------------------------------------------

def sgd(momentum: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return OptState(
            inner=jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params),
            step=jnp.zeros((), jnp.int32))

    def update(grads, state, params, lr, weight_decay=0.0):
        def upd(g, m, p):
            g = g.astype(F32) + weight_decay * p.astype(F32)
            m_new = momentum * m + g
            step_dir = g + momentum * m_new if nesterov else m_new
            return (p.astype(F32) - lr * step_dir).astype(p.dtype), m_new

        out = jax.tree.map(upd, grads, state.inner, params)
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, OptState(inner=new_m, step=state.step + 1)

    return Optimizer(init, update, "sgd")


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          moment_dtype=jnp.float32) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return OptState(
            inner={"m": jax.tree.map(zeros, params),
                   "v": jax.tree.map(zeros, params)},
            step=jnp.zeros((), jnp.int32))

    def update(grads, state, params, lr, weight_decay=0.0):
        t = state.step + 1
        c1 = 1.0 - b1 ** t.astype(F32)
        c2 = 1.0 - b2 ** t.astype(F32)

        def upd(g, m, v, p):
            g = g.astype(F32)
            m_new = b1 * m.astype(F32) + (1 - b1) * g
            v_new = b2 * v.astype(F32) + (1 - b2) * g * g
            mh = m_new / c1
            vh = v_new / c2
            step_dir = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(F32)
            p_new = (p.astype(F32) - lr * step_dir).astype(p.dtype)
            return p_new, m_new.astype(moment_dtype), v_new.astype(moment_dtype)

        out = jax.tree.map(upd, grads, state.inner["m"], state.inner["v"],
                           params)
        is3 = lambda x: isinstance(x, tuple)
        new_p = jax.tree.map(lambda t_: t_[0], out, is_leaf=is3)
        new_m = jax.tree.map(lambda t_: t_[1], out, is_leaf=is3)
        new_v = jax.tree.map(lambda t_: t_[2], out, is_leaf=is3)
        return new_p, OptState(inner={"m": new_m, "v": new_v}, step=t)

    return Optimizer(init, update, "adamw")


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, no momentum)
# ---------------------------------------------------------------------------

def adafactor(decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0) -> Optimizer:
    def _factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def one(p):
            if _factored(p.shape):
                return {"r": jnp.zeros(p.shape[:-1], F32),
                        "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], F32)}
            return {"v": jnp.zeros(p.shape, F32)}

        return OptState(inner=jax.tree.map(one, params),
                        step=jnp.zeros((), jnp.int32))

    def update(grads, state, params, lr, weight_decay=0.0):
        t = state.step + 1
        beta = 1.0 - (t.astype(F32) + 1.0) ** (-decay)

        def upd(g, s, p):
            g = g.astype(F32)
            g2 = g * g + eps
            if _factored(g.shape):
                r = beta * s["r"] + (1 - beta) * g2.mean(-1)
                c = beta * s["c"] + (1 - beta) * g2.mean(-2)
                rc = r / jnp.maximum(r.mean(-1, keepdims=True), 1e-30)
                vhat = rc[..., None] * c[..., None, :]
                s_new = {"r": r, "c": c}
            else:
                vhat = beta * s["v"] + (1 - beta) * g2
                s_new = {"v": vhat}
            u = g * jax.lax.rsqrt(vhat + eps)
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            u = u + weight_decay * p.astype(F32)
            return (p.astype(F32) - lr * u).astype(p.dtype), s_new

        out = jax.tree.map(upd, grads, state.inner, params,
                           is_leaf=lambda x: isinstance(x, dict)
                           and set(x) <= {"r", "c", "v"})
        ist = lambda x: isinstance(x, tuple)
        new_p = jax.tree.map(lambda t_: t_[0], out, is_leaf=ist)
        new_s = jax.tree.map(lambda t_: t_[1], out, is_leaf=ist)
        return new_p, OptState(inner=new_s, step=t)

    return Optimizer(init, update, "adafactor")


def get_optimizer(name: str, **kw) -> Optimizer:
    return {"sgd": sgd, "adamw": adamw, "adafactor": adafactor}[name](**kw)
