"""repro.optim — from-scratch optimizers (no optax in the container)."""
from .optimizers import (
    OptState,
    adafactor,
    adamw,
    clip_by_global_norm,
    get_optimizer,
    sgd,
)
from .schedules import constant, cosine_decay, linear_warmup_cosine

__all__ = [
    "OptState", "adamw", "adafactor", "sgd", "clip_by_global_norm",
    "get_optimizer", "constant", "cosine_decay", "linear_warmup_cosine",
]
