"""LR schedules as step -> lr callables (jittable)."""
from __future__ import annotations

import math

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_decay(lr: float, steps: int, final_frac: float = 0.1):
    def f(step):
        t = jnp.minimum(step.astype(jnp.float32), steps) / steps
        cos = 0.5 * (1 + jnp.cos(math.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)

    return f


def linear_warmup_cosine(lr: float, warmup: int, steps: int,
                         final_frac: float = 0.1):
    cos = cosine_decay(lr, max(steps - warmup, 1), final_frac)

    def f(step):
        step = step.astype(jnp.float32)
        wu = lr * jnp.minimum(step / max(warmup, 1), 1.0)
        return jnp.where(step < warmup, wu, cos(step - warmup))

    return f
