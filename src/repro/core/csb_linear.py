"""CSBLinear: a three-mode linear layer — the CSB technique as a
first-class model feature (DESIGN.md §3).

Modes:
  dense   — plain matmul (training before pruning starts)
  masked  — dense matmul against the CSB-projected weight (ADMM training:
            the projection is the Z-update; the mask is free under jit)
  csb     — the PaddedCSB format through the Pallas kernel (serving).
            When a mesh with a non-trivial "model" axis is active (see
            ``models.layers.csb_dense``), the block grid is partitioned
            over that axis by cycle cost (``dist.csb_partition``) and
            executed via ``csb_matvec_sharded``; ``shard_for_mesh``
            builds and caches the per-mesh ``ShardedCSB``.

`csb_specs_for_params` builds the spec tree that repro.train's ADMM hooks
consume, selecting every >= min_dim 2-D/stacked-3-D projection of a model.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .csb_format import PaddedCSB, padded_csb_from_dense
from .pruning import CSBSpec, csb_masks, csb_project

PyTree = Any


def _active_model_mesh(axis: str = "model"):
    """The mesh from the active ``dist`` Rules when its ``axis`` is
    non-trivial — the signal that the sharded CSB path should run.
    None on single-device paths, so tests/CPU stay on the local kernel."""
    from repro.dist.api import current_rules
    rules = current_rules()
    mesh = getattr(rules, "mesh", None)
    if mesh is None or axis not in tuple(mesh.axis_names):
        return None
    return mesh if mesh.shape[axis] > 1 else None


@dataclasses.dataclass
class CSBLinear:
    """Stateful wrapper around one projection weight."""

    weight: jax.Array                    # (in, out) or (out, in) — caller's
    spec: CSBSpec
    mode: str = "dense"                  # dense | masked | csb
    transposed: bool = False             # True if weight is (in, out)
    _packed: PaddedCSB | None = None
    # (n_dev, axis) -> (PartitionPlan, ShardedCSB); host-side cache so the
    # greedy placement runs once per mesh width, not once per call
    _shards: dict = dataclasses.field(default_factory=dict)

    def _w_oi(self) -> jax.Array:
        return self.weight.T if self.transposed else self.weight

    def freeze(self, pad_to: int = 8) -> "CSBLinear":
        """Project + pack for serving (mode -> csb)."""
        w = np.asarray(csb_project(self._w_oi(), self.spec))
        rm, cm = csb_masks(jnp.asarray(w), self.spec)
        packed = padded_csb_from_dense(
            w, self.spec.bm, self.spec.bn, pad_to=pad_to,
            row_mask=np.asarray(rm), col_mask=np.asarray(cm))
        # fresh shard cache: replace() would alias the dict, and cached
        # shards of the previous packing must not survive a re-freeze
        return dataclasses.replace(self, mode="csb", _packed=packed,
                                   _shards={})

    def shard_for_mesh(self, mesh, axis: str = "model"):
        """(plan, ShardedCSB) for this weight on ``mesh[axis]``, cycle-
        balanced by the greedy planner and cached per mesh width."""
        assert self._packed is not None, "call freeze() first"
        from repro.dist.csb_partition import partition_padded
        n_dev = mesh.shape[axis]
        key = (n_dev, axis)
        if key not in self._shards:
            self._shards[key] = partition_padded(self._packed, n_dev)
        return self._shards[key]

    def __call__(self, x: jax.Array) -> jax.Array:
        if self.mode == "dense":
            w = self._w_oi()
        elif self.mode == "masked":
            w = csb_project(self._w_oi(), self.spec)
        elif self.mode == "csb":
            assert self._packed is not None, "call freeze() first"
            mesh = _active_model_mesh()
            if mesh is not None:
                from repro.kernels.csb_sharded import csb_matvec_sharded
                _, sharded = self.shard_for_mesh(mesh)
                return csb_matvec_sharded(
                    sharded, x, mesh=mesh).astype(x.dtype)
            from repro.kernels.ops import csb_matvec
            return csb_matvec(self._packed, x).astype(x.dtype)
        else:  # pragma: no cover
            raise ValueError(self.mode)
        return jnp.einsum("...i,oi->...o", x, w.astype(x.dtype))

    def compression(self) -> float:
        if self._packed is None:
            return 1.0
        return (self._packed.shape[0] * self._packed.shape[1]
                / max(self._packed.true_flops_per_mvm() // 2, 1))


def csb_specs_for_params(params: PyTree, spec: CSBSpec,
                         min_dim: int = 64,
                         exclude: tuple[str, ...] = ("embed", "head",
                                                     "router")) -> PyTree:
    """Spec tree (CSBSpec | None per leaf) for ADMM pruning of a model's
    projections — 2-D weights and stacked (L, in, out) layer weights."""

    def assign(path, leaf):
        keys = [getattr(k, "key", str(k)) for k in path]
        if any(e in keys[-1] for e in exclude):
            return None
        if leaf.ndim == 2 and min(leaf.shape) >= min_dim:
            return spec
        if leaf.ndim == 3 and min(leaf.shape[1:]) >= min_dim \
                and "layers" in keys:
            return spec
        return None

    return jax.tree_util.tree_map_with_path(assign, params)
