"""CSBLinear: a three-mode linear layer — the CSB technique as a
first-class model feature (DESIGN.md §3).

Modes:
  dense   — plain matmul (training before pruning starts)
  masked  — dense matmul against the CSB-projected weight (ADMM training:
            the projection is the Z-update; the mask is free under jit)
  csb     — the PaddedCSB format through the Pallas kernel (serving)

`csb_specs_for_params` builds the spec tree that repro.train's ADMM hooks
consume, selecting every >= min_dim 2-D/stacked-3-D projection of a model.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .csb_format import PaddedCSB, padded_csb_from_dense
from .pruning import CSBSpec, csb_masks, csb_project

PyTree = Any


@dataclasses.dataclass
class CSBLinear:
    """Stateful wrapper around one projection weight."""

    weight: jax.Array                    # (in, out) or (out, in) — caller's
    spec: CSBSpec
    mode: str = "dense"                  # dense | masked | csb
    transposed: bool = False             # True if weight is (in, out)
    _packed: PaddedCSB | None = None

    def _w_oi(self) -> jax.Array:
        return self.weight.T if self.transposed else self.weight

    def freeze(self, pad_to: int = 8) -> "CSBLinear":
        """Project + pack for serving (mode -> csb)."""
        w = np.asarray(csb_project(self._w_oi(), self.spec))
        rm, cm = csb_masks(jnp.asarray(w), self.spec)
        packed = padded_csb_from_dense(
            w, self.spec.bm, self.spec.bn, pad_to=pad_to,
            row_mask=np.asarray(rm), col_mask=np.asarray(cm))
        return dataclasses.replace(self, mode="csb", _packed=packed)

    def __call__(self, x: jax.Array) -> jax.Array:
        if self.mode == "dense":
            w = self._w_oi()
        elif self.mode == "masked":
            w = csb_project(self._w_oi(), self.spec)
        elif self.mode == "csb":
            from repro.kernels.ops import csb_matvec
            assert self._packed is not None, "call freeze() first"
            return csb_matvec(self._packed, x).astype(x.dtype)
        else:  # pragma: no cover
            raise ValueError(self.mode)
        return jnp.einsum("...i,oi->...o", x, w.astype(x.dtype))

    def compression(self) -> float:
        if self._packed is None:
            return 1.0
        return (self._packed.shape[0] * self._packed.shape[1]
                / max(self._packed.true_flops_per_mvm() // 2, 1))


def csb_specs_for_params(params: PyTree, spec: CSBSpec,
                         min_dim: int = 64,
                         exclude: tuple[str, ...] = ("embed", "head",
                                                     "router")) -> PyTree:
    """Spec tree (CSBSpec | None per leaf) for ADMM pruning of a model's
    projections — 2-D weights and stacked (L, in, out) layer weights."""

    def assign(path, leaf):
        keys = [getattr(k, "key", str(k)) for k in path]
        if any(e in keys[-1] for e in exclude):
            return None
        if leaf.ndim == 2 and min(leaf.shape) >= min_dim:
            return spec
        if leaf.ndim == 3 and min(leaf.shape[1:]) >= min_dim \
                and "layers" in keys:
            return spec
        return None

    return jax.tree_util.tree_map_with_path(assign, params)
