"""The CSB sparse storage format (paper Fig. 3) and its device-side
padded twin.

``CSBMatrix`` is the *faithful* format: five arrays in three groups —
per-block kernel dims ``m{}``/``n{}``, survivor indices ``RowIdx{}``/
``ColIdx{}`` and the concatenated kernel values ``Val{}`` in block
row-major order (no per-block offsets: access is sequential, exactly as
the paper stores it). It is a host-side (numpy, ragged) object used for
storage accounting (NIO), serialization, and as the compiler's input.

``PaddedCSB`` is the TPU-friendly twin: every kernel is padded to a common
``(Pm, Pn)`` (MXU-aligned bucket) so the whole matrix becomes four dense
arrays a Pallas kernel can tile. Padding is *explicitly accounted* —
the scheduler (engine/schedule.py) balances on real kernel FLOPs while the
kernel masks the pad lanes.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@dataclasses.dataclass
class CSBMatrix:
    """Faithful CSB format (ragged, host side)."""

    shape: tuple[int, int]            # original (out, in)
    bm: int
    bn: int
    m: np.ndarray                     # (Br, Bc) int32 — kernel rows/block
    n: np.ndarray                     # (Br, Bc) int32 — kernel cols/block
    row_idx: np.ndarray               # (sum m,) int32, block row-major
    col_idx: np.ndarray               # (sum n,) int32
    val: np.ndarray                   # (sum m*n,) kernel values, row-major

    # -- construction ------------------------------------------------------
    @classmethod
    def from_dense(
        cls, w: np.ndarray, bm: int, bn: int,
        row_mask: np.ndarray | None = None,
        col_mask: np.ndarray | None = None,
    ) -> "CSBMatrix":
        """Encode a CSB-patterned dense matrix.

        If masks (from ``core.pruning.csb_masks``) are not given, survivors
        are inferred from the nonzero pattern (a row/col of a block survives
        iff it has any nonzero).
        """
        w = np.asarray(w)
        out_dim, in_dim = w.shape
        br, bc = -(-out_dim // bm), -(-in_dim // bn)
        wp = np.zeros((br * bm, bc * bn), w.dtype)
        wp[:out_dim, :in_dim] = w
        blocks = wp.reshape(br, bm, bc, bn).transpose(0, 2, 1, 3)

        if row_mask is None:
            nz = blocks != 0
            row_mask = nz.any(axis=3)
            col_mask = nz.any(axis=2)
        row_mask = np.asarray(row_mask, bool)
        col_mask = np.asarray(col_mask, bool)
        # CSB cross-point property: a survivor row with no surviving col
        # stores nothing; normalize so m,n are consistent with storage.
        has_any = row_mask.any(-1) & col_mask.any(-1)        # (Br, Bc)
        row_mask = row_mask & has_any[..., None]
        col_mask = col_mask & has_any[..., None]

        m = row_mask.sum(-1).astype(np.int32)
        n = col_mask.sum(-1).astype(np.int32)
        rows, cols, vals = [], [], []
        for i in range(br):
            for j in range(bc):
                r = np.nonzero(row_mask[i, j])[0].astype(np.int32)
                c = np.nonzero(col_mask[i, j])[0].astype(np.int32)
                rows.append(r)
                cols.append(c)
                vals.append(blocks[i, j][np.ix_(r, c)].reshape(-1))
        return cls(
            shape=(out_dim, in_dim), bm=bm, bn=bn, m=m, n=n,
            row_idx=np.concatenate(rows) if rows else np.zeros(0, np.int32),
            col_idx=np.concatenate(cols) if cols else np.zeros(0, np.int32),
            val=np.concatenate(vals) if vals else np.zeros(0, w.dtype),
        )

    # -- decode ------------------------------------------------------------
    def to_dense(self) -> np.ndarray:
        br, bc = self.m.shape
        out = np.zeros((br * self.bm, bc * self.bn), self.val.dtype)
        ro = co = vo = 0
        for i in range(br):
            for j in range(bc):
                mi, ni = int(self.m[i, j]), int(self.n[i, j])
                r = self.row_idx[ro: ro + mi]
                c = self.col_idx[co: co + ni]
                k = self.val[vo: vo + mi * ni].reshape(mi, ni)
                out[np.ix_(i * self.bm + r, j * self.bn + c)] = k
                ro, co, vo = ro + mi, co + ni, vo + mi * ni
        return out[: self.shape[0], : self.shape[1]]

    # -- storage accounting (Fig. 10b) --------------------------------------
    @property
    def nnz(self) -> int:
        return int((self.m.astype(np.int64) * self.n).sum())

    @property
    def index_count(self) -> int:
        """Row + col survivor indices (+2 counts per block)."""
        return int(self.m.sum() + self.n.sum() + 2 * self.m.size)

    def nio(self) -> float:
        """Normalized Index Overhead = index entries / weight entries."""
        return self.index_count / max(self.nnz, 1)

    @staticmethod
    def csr_nio(nnz: int, rows: int) -> float:
        """CSR overhead of a non-structured matrix: 1 col idx per nnz +
        row pointers — the paper's >100% comparison point."""
        return (nnz + rows + 1) / max(nnz, 1)

    def compression_ratio(self) -> float:
        return (self.shape[0] * self.shape[1]) / max(self.nnz, 1)

    # -- workload view for the engine/compiler ------------------------------
    def block_workloads(self) -> np.ndarray:
        """(Br, Bc) multiply-accumulate counts — the scheduler's input."""
        return (self.m.astype(np.int64) * self.n.astype(np.int64))


def _register_pytree(cls):
    fields = [f.name for f in dataclasses.fields(cls) if f.metadata.get("leaf")]
    aux = [f.name for f in dataclasses.fields(cls) if not f.metadata.get("leaf")]

    def flatten(obj):
        return [getattr(obj, k) for k in fields], tuple(
            getattr(obj, k) for k in aux
        )

    def unflatten(auxv, leaves):
        kw = dict(zip(fields, leaves))
        kw.update(dict(zip(aux, auxv)))
        return cls(**kw)

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)
    return cls


def _leaf(**kw):
    return dataclasses.field(metadata={"leaf": True}, **kw)


@_register_pytree
@dataclasses.dataclass
class PaddedCSB:
    """Device-side CSB: kernels padded to a common (Pm, Pn) bucket.

    vals:     (NB, Pm, Pn)  kernel values (pad lanes zero)
    row_idx:  (NB, Pm) int32  within-block survivor row (pad -> 0)
    col_idx:  (NB, Pn) int32
    m, n:     (NB,) int32   true kernel dims
    Blocks are row-major over the (Br, Bc) grid.
    """

    vals: jax.Array = _leaf()
    row_idx: jax.Array = _leaf()
    col_idx: jax.Array = _leaf()
    m: jax.Array = _leaf()
    n: jax.Array = _leaf()
    shape: tuple[int, int] = dataclasses.field(default=(0, 0))
    grid: tuple[int, int] = dataclasses.field(default=(0, 0))
    block: tuple[int, int] = dataclasses.field(default=(0, 0))

    @property
    def pm(self) -> int:
        return self.vals.shape[1]

    @property
    def pn(self) -> int:
        return self.vals.shape[2]

    @classmethod
    def from_csb(
        cls, csb: CSBMatrix, pad_to: int = 8, dtype=jnp.float32
    ) -> "PaddedCSB":
        br, bc = csb.m.shape
        nb = br * bc
        pm = max(_round_up(int(csb.m.max(initial=0)), pad_to), pad_to)
        pn = max(_round_up(int(csb.n.max(initial=0)), pad_to), pad_to)
        vals = np.zeros((nb, pm, pn), np.float32)
        ridx = np.zeros((nb, pm), np.int32)
        cidx = np.zeros((nb, pn), np.int32)
        ro = co = vo = 0
        b = 0
        for i in range(br):
            for j in range(bc):
                mi, ni = int(csb.m[i, j]), int(csb.n[i, j])
                ridx[b, :mi] = csb.row_idx[ro: ro + mi]
                cidx[b, :ni] = csb.col_idx[co: co + ni]
                vals[b, :mi, :ni] = csb.val[vo: vo + mi * ni].reshape(mi, ni)
                ro, co, vo = ro + mi, co + ni, vo + mi * ni
                b += 1
        return cls(
            vals=jnp.asarray(vals, dtype),
            row_idx=jnp.asarray(ridx),
            col_idx=jnp.asarray(cidx),
            m=jnp.asarray(csb.m.reshape(-1)),
            n=jnp.asarray(csb.n.reshape(-1)),
            shape=csb.shape, grid=(br, bc), block=(csb.bm, csb.bn),
        )

    def padded_flops_per_mvm(self) -> int:
        """2 * NB * Pm * Pn — what the padded kernel actually executes."""
        return 2 * int(self.vals.shape[0]) * self.pm * self.pn

    def true_flops_per_mvm(self) -> int:
        return int(2 * jnp.sum(self.m.astype(jnp.int64) * self.n))

    # -- device sharding (mesh-level balancing, paper §5.2 lifted) ----------
    def split_block_rows(
        self, assignment: Sequence[Sequence[int]]
    ) -> "ShardedCSB":
        """Split the block grid over devices by BLOCK-ROW.

        ``assignment[d]`` lists the global block-row ids device ``d``
        owns (an arbitrary partition of ``range(Br)`` — the planner in
        ``repro.dist.csb_partition`` picks it by cycle cost). Devices
        with fewer rows are padded with empty rows (``m = n = 0``
        blocks, which the kernel masks to zero), so every device shard
        has identical shape and the stack can be laid out with a plain
        leading-axis PartitionSpec.
        """
        br, bc = self.grid
        n_dev = len(assignment)
        flat = sorted(r for rows in assignment for r in rows)
        if flat != list(range(br)):
            raise ValueError(
                f"assignment must partition range({br}), got {assignment}")
        rpd = max((len(rows) for rows in assignment), default=0)
        rpd = max(rpd, 1)
        gather = np.zeros((n_dev, rpd), np.int32)
        valid = np.zeros((n_dev, rpd), bool)
        for d, rows in enumerate(assignment):
            gather[d, : len(rows)] = rows
            valid[d, : len(rows)] = True

        pm, pn = self.pm, self.pn
        vals4 = self.vals.reshape(br, bc, pm, pn)
        ridx3 = self.row_idx.reshape(br, bc, pm)
        cidx3 = self.col_idx.reshape(br, bc, pn)
        m2 = self.m.reshape(br, bc)
        n2 = self.n.reshape(br, bc)
        g = jnp.asarray(gather)
        v = jnp.asarray(valid)
        live = v[:, :, None]                               # (D, R, 1)
        return ShardedCSB(
            vals=vals4[g].reshape(n_dev, rpd * bc, pm, pn),
            row_idx=ridx3[g].reshape(n_dev, rpd * bc, pm),
            col_idx=cidx3[g].reshape(n_dev, rpd * bc, pn),
            m=jnp.where(live, m2[g], 0).reshape(n_dev, rpd * bc),
            n=jnp.where(live, n2[g], 0).reshape(n_dev, rpd * bc),
            shape=self.shape, grid=self.grid, block=self.block,
            row_map=tuple(tuple(rows) for rows in assignment),
        )


@_register_pytree
@dataclasses.dataclass
class ShardedCSB:
    """A ``PaddedCSB`` split over devices by block-row (shard metadata
    view): every array gains a leading device axis sized ``n_dev``, and
    ``row_map`` records which global block-rows each device owns (in
    local-slot order) so outputs can be permuted back after the
    all-gather. Built via :meth:`PaddedCSB.split_block_rows`; consumed
    by ``repro.kernels.csb_sharded.csb_matvec_sharded``.
    """

    vals: jax.Array = _leaf()       # (D, R*Bc, Pm, Pn)
    row_idx: jax.Array = _leaf()    # (D, R*Bc, Pm)
    col_idx: jax.Array = _leaf()    # (D, R*Bc, Pn)
    m: jax.Array = _leaf()          # (D, R*Bc) — 0 on pad rows
    n: jax.Array = _leaf()          # (D, R*Bc)
    shape: tuple[int, int] = dataclasses.field(default=(0, 0))
    grid: tuple[int, int] = dataclasses.field(default=(0, 0))
    block: tuple[int, int] = dataclasses.field(default=(0, 0))
    # per-device global block-row ids, local-slot order (hashable aux data)
    row_map: tuple[tuple[int, ...], ...] = dataclasses.field(default=())

    @property
    def n_dev(self) -> int:
        return self.vals.shape[0]

    @property
    def rows_per_dev(self) -> int:
        return self.vals.shape[1] // self.grid[1]

    @property
    def pm(self) -> int:
        return self.vals.shape[-2]

    @property
    def pn(self) -> int:
        return self.vals.shape[-1]

    def output_permutation(self) -> np.ndarray:
        """``perm`` s.t. ``y_global[:, i] = y_gathered[:, perm[i]]`` where
        ``y_gathered`` concatenates per-device outputs (pad rows
        included) in device order."""
        return csb_output_permutation(
            self.row_map, self.rows_per_dev, self.block[0], self.grid[0])


def csb_output_permutation(row_map, rows_per_dev: int, bm: int,
                           br: int) -> np.ndarray:
    """Gather-order -> block-row-order output permutation (see
    :meth:`ShardedCSB.output_permutation`; standalone so the kernel's
    jit-cache can rebuild it from hashable statics alone)."""
    perm = np.zeros(br * bm, np.int64)
    for d, rows in enumerate(row_map):
        for s, r in enumerate(rows):
            src = (d * rows_per_dev + s) * bm
            perm[r * bm: (r + 1) * bm] = np.arange(src, src + bm)
    return perm


def padded_csb_from_dense(
    w, bm: int, bn: int, pad_to: int = 8, dtype=jnp.float32,
    row_mask=None, col_mask=None,
) -> PaddedCSB:
    csb = CSBMatrix.from_dense(
        np.asarray(w), bm, bn,
        None if row_mask is None else np.asarray(row_mask),
        None if col_mask is None else np.asarray(col_mask),
    )
    return PaddedCSB.from_csb(csb, pad_to=pad_to, dtype=dtype)
