"""Progressive lossless-pruning-rate controller (paper Algorithm 1).

The outer loop of Algorithm 1: starting from ``init_pr`` (a surely-lossless
compression), the prune rate grows by ``step``; once accuracy drops below
the lossless target the step is halved and the rate backs off — a
binary-search refinement that terminates when
``step <= init_step / 4`` and the last evaluation was lossless.

The controller is deliberately pure-Python state (it is *driven by* train /
eval callbacks), so it composes with any training loop and is trivially
checkpointable.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class ProgressiveState:
    prune_rate: float
    step: float
    flag: bool = False          # 'over-pruned seen' flag from Algorithm 1
    done: bool = False
    best_lossless_rate: float = 0.0
    iterations: int = 0


class ProgressivePruner:
    """Drives Algorithm 1's outer loop.

    >>> ctl = ProgressivePruner(init_pr=0.25, init_step=0.25)
    >>> while not ctl.done:
    ...     rate = ctl.prune_rate        # train+ADMM-prune at this rate
    ...     ok = evaluate() >= lossless  # Eval(Z) >= accu
    ...     ctl.update(ok)
    """

    def __init__(self, init_pr: float = 0.25, init_step: float = 0.25,
                 max_rate: float = 0.995):
        if not 0.0 < init_pr < 1.0:
            raise ValueError(f"init_pr must be in (0,1): {init_pr}")
        self.init_step = float(init_step)
        self.max_rate = float(max_rate)
        self.state = ProgressiveState(prune_rate=float(init_pr),
                                      step=float(init_step))

    # -- protocol -----------------------------------------------------------
    @property
    def prune_rate(self) -> float:
        return self.state.prune_rate

    @property
    def done(self) -> bool:
        return self.state.done

    @property
    def best_lossless_rate(self) -> float:
        return self.state.best_lossless_rate

    @property
    def best_compression(self) -> float:
        return 1.0 / max(1.0 - self.state.best_lossless_rate, 1e-12)

    def update(self, lossless: bool) -> None:
        """Feed the result of Eval(Z) >= accu for the current rate."""
        s = self.state
        if s.done:
            return
        s.iterations += 1
        if lossless:
            s.best_lossless_rate = max(s.best_lossless_rate, s.prune_rate)
            # Termination test (paper: step <= init_step/4 and Eval ok).
            if s.step <= self.init_step / 4 + 1e-12:
                s.done = True
                return
            if s.flag:
                s.step = s.step / 2
            s.prune_rate = min(s.prune_rate + s.step, self.max_rate)
        else:
            s.flag = True
            s.step = s.step / 2
            s.prune_rate = max(s.prune_rate - s.step, 0.0)
            if s.step <= self.init_step / 16:
                # Degenerate guard: cannot refine further; settle at the
                # best lossless rate seen.
                s.prune_rate = s.best_lossless_rate
                s.done = s.best_lossless_rate > 0.0

    def __repr__(self) -> str:  # pragma: no cover
        s = self.state
        return (f"ProgressivePruner(rate={s.prune_rate:.4f}, step={s.step:.4f},"
                f" best={s.best_lossless_rate:.4f}, done={s.done})")
