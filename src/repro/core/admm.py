"""ADMM-based training-with-pruning (paper §2.2.2, Eqns. 2-6).

The training objective is split: SGD minimizes
``f(W) + sum_i rho/2 ||W_i - Z_i + U_i||^2``  (Eqn. 4)
while ``Z_i = proj_S(W_i + U_i)``             (Eqn. 5/6, the CSB projection)
and the dual update is ``U_i += W_i - Z_i``.

The API is functional: an ``ADMMState`` pytree rides next to the params.
Only parameters with an entry in the spec-tree participate; everything
else (biases, norms, embeddings) is untouched — the paper prunes weight
matrices only ("the bias vector is omitted").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .pruning import CSBSpec, csb_project

PyTree = Any


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ADMMState:
    z: PyTree     # auxiliary (projected) variables, same tree as pruned params
    u: PyTree     # scaled dual variables
    rho: float

    def tree_flatten(self):
        return (self.z, self.u), (self.rho,)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(z=leaves[0], u=leaves[1], rho=aux[0])


def _is_spec(x) -> bool:
    return isinstance(x, CSBSpec)


def spec_tree_map(fn: Callable, specs: PyTree, *trees: PyTree) -> PyTree:
    """tree_map over (spec, param, ...) treating CSBSpec as leaves."""
    return jax.tree.map(fn, specs, *trees, is_leaf=lambda x: _is_spec(x) or x is None)


def admm_init(params: PyTree, specs: PyTree, rho: float = 1e-3) -> ADMMState:
    """specs mirrors ``params`` with CSBSpec leaves (None = not pruned)."""
    z = spec_tree_map(
        lambda s, w: csb_project(w, s) if _is_spec(s) else None, specs, params
    )
    u = spec_tree_map(
        lambda s, w: jnp.zeros_like(w) if _is_spec(s) else None, specs, params
    )
    return ADMMState(z=z, u=u, rho=rho)


def admm_penalty(params: PyTree, state: ADMMState, specs: PyTree) -> jax.Array:
    """rho/2 * sum ||W - Z + U||_F^2 — add to the task loss (Eqn. 4)."""

    def term(s, w, z, u):
        if not _is_spec(s):
            return 0.0
        d = w.astype(jnp.float32) - z + u
        return 0.5 * state.rho * jnp.sum(d * d)

    terms = spec_tree_map(term, specs, params, state.z, state.u)
    return jax.tree.reduce(
        lambda a, b: a + b, terms, 0.0, is_leaf=lambda x: x is None
    )


def admm_update(params: PyTree, state: ADMMState, specs: PyTree) -> ADMMState:
    """Solve the 2nd subproblem (projection) + dual ascent. Call once per
    epoch (or every k steps)."""

    def proj(s, w, u):
        if not _is_spec(s):
            return None
        return csb_project(w.astype(jnp.float32) + u, s)

    z = spec_tree_map(proj, specs, params, state.u)

    def dual(s, w, z_, u):
        if not _is_spec(s):
            return None
        return u + w.astype(jnp.float32) - z_

    u = spec_tree_map(dual, specs, params, z, state.u)
    return ADMMState(z=z, u=u, rho=state.rho)


def admm_finalize(params: PyTree, specs: PyTree) -> PyTree:
    """Hard-project the trained weights onto the CSB pattern (the shipped
    model). Retraining with the mask fixed can follow."""

    def fin(s, w):
        return csb_project(w, s).astype(w.dtype) if _is_spec(s) else w

    return spec_tree_map(fin, specs, params)


def residual_norm(params: PyTree, state: ADMMState, specs: PyTree) -> jax.Array:
    """||W - Z|| convergence diagnostic."""

    def term(s, w, z):
        if not _is_spec(s):
            return 0.0
        d = w.astype(jnp.float32) - z
        return jnp.sum(d * d)

    terms = spec_tree_map(term, specs, params, state.z)
    total = jax.tree.reduce(lambda a, b: a + b, terms, 0.0,
                            is_leaf=lambda x: x is None)
    return jnp.sqrt(total)
