"""repro.core — the paper's primary contribution: CSB pruning.

Public surface:
  CSBSpec, csb_project, csb_masks, kernel_sizes    (projection, Alg. 1 inner)
  magnitude_project, bank_balanced_project, row_column_project  (baselines)
  CSBMatrix, PaddedCSB, ShardedCSB, padded_csb_from_dense  (formats, Fig. 3)
  ADMMState, admm_init/penalty/update/finalize     (Eqns. 2-6)
  ProgressivePruner                                (Alg. 1 outer loop)
"""
from .pruning import (
    CSBSpec,
    bank_balanced_project,
    csb_masks,
    csb_project,
    density,
    element_mask,
    from_blocks,
    kernel_sizes,
    magnitude_project,
    row_column_project,
    to_blocks,
)
from .csb_format import (
    CSBMatrix, PaddedCSB, ShardedCSB, padded_csb_from_dense,
)
from .admm import (
    ADMMState,
    admm_finalize,
    admm_init,
    admm_penalty,
    admm_update,
    residual_norm,
    spec_tree_map,
)
from .progressive import ProgressivePruner, ProgressiveState
from .csb_linear import CSBLinear, csb_specs_for_params

__all__ = [
    "CSBSpec", "csb_project", "csb_masks", "kernel_sizes", "density",
    "element_mask", "to_blocks", "from_blocks",
    "magnitude_project", "bank_balanced_project", "row_column_project",
    "CSBMatrix", "PaddedCSB", "ShardedCSB", "padded_csb_from_dense",
    "ADMMState", "admm_init", "admm_penalty", "admm_update",
    "admm_finalize", "residual_norm", "spec_tree_map",
    "ProgressivePruner", "ProgressiveState",
    "CSBLinear", "csb_specs_for_params",
]
