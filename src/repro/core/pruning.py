"""CSB projection (Algorithm 1's RowPrune/ColumnPrune) — the Euclidean
projection onto the CSB-constrained set S (Eqn. 6 of the paper).

Conventions
-----------
A weight matrix has shape ``(out_dim, in_dim)``; it is tiled into
``Br x Bc`` blocks of ``(bm, bn)`` (zero-padded when not divisible, as the
paper does for SR4). Within each *block-column* a fraction of rows is
pruned globally by l2-norm (RowPrune), then within each *block-row* a
fraction of columns (ColumnPrune). Because the thresholds are global per
block-column/-row, the per-block kernel sizes ``m(i,j) x n(i,j)`` vary —
the "natural unbalanced sparsity" the paper's engine must then balance.

Per Algorithm 1 both passes use rate ``1 - sqrt(1 - prune_rate)`` so the
combined kept fraction is ``~ 1 - prune_rate``.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CSBSpec:
    """Pruning spec for one weight matrix."""

    bm: int = 32          # block rows (output-neuron slice)
    bn: int = 32          # block cols (input-neuron slice)
    prune_rate: float = 0.5  # fraction of weights REMOVED, in [0, 1)

    @property
    def keep_fraction(self) -> float:
        return 1.0 - self.prune_rate

    @property
    def compression_ratio(self) -> float:
        """Paper's headline 'pruning rate' (e.g. 25x) = orig/pruned."""
        return 1.0 / max(self.keep_fraction, 1e-12)

    def with_rate(self, prune_rate: float) -> "CSBSpec":
        return dataclasses.replace(self, prune_rate=float(prune_rate))


def _grid(shape: tuple[int, int], bm: int, bn: int) -> tuple[int, int]:
    out_dim, in_dim = shape
    return -(-out_dim // bm), -(-in_dim // bn)


def pad_to_blocks(w: jax.Array, bm: int, bn: int) -> jax.Array:
    out_dim, in_dim = w.shape
    br, bc = _grid(w.shape, bm, bn)
    return jnp.pad(w, ((0, br * bm - out_dim), (0, bc * bn - in_dim)))


def to_blocks(w: jax.Array, bm: int, bn: int) -> jax.Array:
    """(out, in) -> (Br, Bc, bm, bn)."""
    br, bc = _grid(w.shape, bm, bn)
    wp = pad_to_blocks(w, bm, bn)
    return wp.reshape(br, bm, bc, bn).transpose(0, 2, 1, 3)


def from_blocks(blocks: jax.Array, shape: tuple[int, int]) -> jax.Array:
    br, bc, bm, bn = blocks.shape
    wp = blocks.transpose(0, 2, 1, 3).reshape(br * bm, bc * bn)
    return wp[: shape[0], : shape[1]]


def _topk_mask(scores: jax.Array, keep: int) -> jax.Array:
    """Exact-count keep mask of the ``keep`` largest entries along axis -1.

    Argsort-based so ties (e.g. zero padding) never inflate the kept count.
    """
    n = scores.shape[-1]
    order = jnp.argsort(jnp.argsort(scores, axis=-1), axis=-1)  # rank, asc
    return order >= (n - keep)


def csb_masks(
    w: jax.Array, spec: CSBSpec
) -> tuple[jax.Array, jax.Array]:
    """Compute per-block row/col keep masks for the CSB projection.

    Returns ``row_mask (Br, Bc, bm)`` and ``col_mask (Br, Bc, bn)`` (bool).
    Rank-3 inputs (stacked layers, leading L axis) are vmapped.
    """
    if w.ndim == 3:
        return jax.vmap(lambda x: csb_masks(x, spec))(w)
    bm, bn = spec.bm, spec.bn
    blocks = to_blocks(w, bm, bn)           # (Br, Bc, bm, bn)
    br, bc = blocks.shape[:2]
    q = 1.0 - math.sqrt(max(1.0 - spec.prune_rate, 0.0))

    # --- RowPrune: per block-column, over all Br*bm row slices ----------
    rn = jnp.sum(blocks * blocks, axis=3)   # (Br, Bc, bm)
    keep_r = max(int(round((1.0 - q) * br * bm)), 1)
    rn_col = rn.transpose(1, 0, 2).reshape(bc, br * bm)
    row_mask = _topk_mask(rn_col, keep_r)
    row_mask = row_mask.reshape(bc, br, bm).transpose(1, 0, 2)  # (Br,Bc,bm)

    # --- ColumnPrune: per block-row, on the row-masked blocks -----------
    masked = blocks * row_mask[..., :, None]
    cn = jnp.sum(masked * masked, axis=2)   # (Br, Bc, bn)
    keep_c = max(int(round((1.0 - q) * bc * bn)), 1)
    cn_row = cn.reshape(br, bc * bn)
    col_mask = _topk_mask(cn_row, keep_c).reshape(br, bc, bn)

    return row_mask, col_mask


def element_mask(
    shape: tuple[int, int], spec: CSBSpec,
    row_mask: jax.Array, col_mask: jax.Array,
) -> jax.Array:
    """Expand block row/col masks to a dense (out, in) element mask."""
    full = row_mask[..., :, None] & col_mask[..., None, :]
    return from_blocks(full, shape)


@partial(jax.jit, static_argnames=("spec",))
def csb_project(w: jax.Array, spec: CSBSpec) -> jax.Array:
    """Project ``w`` onto the CSB pattern: Z = proj_S(w) (Eqn. 6).

    Rank-3 inputs (stacked layers) are projected per-layer via vmap."""
    if w.ndim == 3:
        return jax.vmap(lambda x: csb_project(x, spec))(w)
    row_mask, col_mask = csb_masks(w, spec)
    return w * element_mask(w.shape, spec, row_mask, col_mask).astype(w.dtype)


def kernel_sizes(
    w: jax.Array, spec: CSBSpec
) -> tuple[jax.Array, jax.Array]:
    """Per-block kernel dims ``m (Br,Bc)``, ``n (Br,Bc)`` of a CSB matrix."""
    row_mask, col_mask = csb_masks(w, spec)
    return row_mask.sum(-1), col_mask.sum(-1)


def density(w: jax.Array) -> jax.Array:
    return jnp.mean((w != 0).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Baselines the paper compares against (Table 2) — implemented for the
# benchmark harness, same projection API.
# ---------------------------------------------------------------------------

def magnitude_project(w: jax.Array, prune_rate: float) -> jax.Array:
    """Non-structured (random-sparsity) magnitude pruning [Han et al.]."""
    flat = jnp.abs(w).reshape(-1)
    keep = max(int(round((1.0 - prune_rate) * flat.size)), 1)
    mask = _topk_mask(flat, keep).reshape(w.shape)
    return w * mask.astype(w.dtype)


def bank_balanced_project(
    w: jax.Array, prune_rate: float, bank: int = 64
) -> jax.Array:
    """Bank-balanced sparsity [Cao et al. FPGA'19]: equal nnz per bank
    (contiguous segments of each row)."""
    out_dim, in_dim = w.shape
    nb = -(-in_dim // bank)
    wp = jnp.pad(w, ((0, 0), (0, nb * bank - in_dim)))
    banks = jnp.abs(wp).reshape(out_dim, nb, bank)
    keep = max(int(round((1.0 - prune_rate) * bank)), 1)
    mask = _topk_mask(banks, keep).reshape(out_dim, nb * bank)
    return w * mask[:, :in_dim].astype(w.dtype)


def row_column_project(w: jax.Array, prune_rate: float) -> jax.Array:
    """Coarse structured pruning [Wen et al. ISS]: whole rows/cols of the
    *entire matrix* (CSB with a single block)."""
    spec = CSBSpec(bm=w.shape[0], bn=w.shape[1], prune_rate=prune_rate)
    return csb_project(w, spec)
