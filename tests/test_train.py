"""Training loop, checkpoint fault-tolerance, optimizers, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CSBSpec, csb_project, density
from repro.data import CharLMTask, Prefetcher
from repro.models import ModelConfig, forward_loss, init_params
from repro.optim import adafactor, adamw, clip_by_global_norm, sgd
from repro.train import TrainConfig, train
from repro.train import checkpoint as ckpt

CFG = ModelConfig(name="tiny", mixer="attn", ffn="swiglu", n_layers=2,
                  d_model=32, n_heads=2, n_kv=2, head_dim=16, d_ff=64,
                  vocab=32, dtype="float32", logit_chunk=16, remat=False)


def _batches(task, steps, batch=8, seq=32, start=0):
    for step in range(start, steps):
        yield step, {k: jnp.asarray(v)
                     for k, v in task.batch(step, batch, seq).items()}


def test_loss_goes_down():
    task = CharLMTask(vocab=32, seed=0)
    params = init_params(jax.random.PRNGKey(0), CFG)
    tcfg = TrainConfig(lr=3e-3, steps=30, log_every=1000, clip_norm=1.0)
    params, hist = train(
        lambda p, b: forward_loss(p, b, CFG), params,
        _batches(task, 30), tcfg, log=lambda *_: None)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.1, (first, last)


def test_compressed_grads_loss_parity():
    """TrainConfig.compress_grads routes every gradient through the int8
    error-feedback compressor (the DP all-reduce wire stage). EF-SGD
    guarantees the transmitted sum tracks the true sum: after 50 steps
    the loss must sit within 1e-2 of the uncompressed run, and training
    must still actually learn."""
    import dataclasses

    task = CharLMTask(vocab=32, seed=2)
    base = TrainConfig(lr=3e-3, steps=50, log_every=1000, clip_norm=1.0)
    runs = {}
    for compress in (False, True):
        params = init_params(jax.random.PRNGKey(0), CFG)
        tcfg = dataclasses.replace(base, compress_grads=compress)
        _, hist = train(lambda p, b: forward_loss(p, b, CFG), params,
                        _batches(task, 50), tcfg, log=lambda *_: None)
        runs[compress] = hist
    plain = runs[False][-1]["loss"]
    comp = runs[True][-1]["loss"]
    assert abs(plain - comp) <= 1e-2, (plain, comp)
    first = np.mean([h["loss"] for h in runs[True][:5]])
    assert comp < first - 0.1, (first, comp)


def test_train_with_admm_prunes():
    task = CharLMTask(vocab=32, seed=1)
    params = init_params(jax.random.PRNGKey(1), CFG)
    specs = jax.tree.map(lambda _: None, params)
    # prune the attention projections of the stacked layers
    specs["layers"]["mixer"]["wq"] = CSBSpec(bm=8, bn=8, prune_rate=0.5)
    specs["layers"]["mixer"]["wo"] = CSBSpec(bm=8, bn=8, prune_rate=0.5)
    tcfg = TrainConfig(lr=3e-3, steps=20, admm_every=5, admm_rho=0.05,
                       log_every=1000)
    params, _ = train(lambda p, b: forward_loss(p, b, CFG), params,
                      _batches(task, 20), tcfg, csb_specs=specs,
                      log=lambda *_: None)
    d = float(density(params["layers"]["mixer"]["wq"]))
    assert d <= 0.56, d  # ~keep fraction (cross-point rounding)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12).reshape(3, 4).astype(jnp.float32),
            "b": {"c": jnp.ones((5,), jnp.bfloat16)}}
    path = ckpt.save(str(tmp_path), 7, tree, extra={"note": "x"})
    assert os.path.isdir(path)
    restored, extra = ckpt.restore(str(tmp_path), 7, tree)
    assert extra == {"note": "x"}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_detects_corruption(tmp_path):
    tree = {"a": jnp.ones((4, 4))}
    ckpt.save(str(tmp_path), 1, tree)
    # corrupt the npz
    f = os.path.join(str(tmp_path), "step_00000001", "arrays.npz")
    data = bytearray(open(f, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(f, "wb").write(bytes(data))
    with pytest.raises(Exception):
        ckpt.restore(str(tmp_path), 1, tree)


def test_checkpoint_latest_and_gc(tmp_path):
    tree = {"a": jnp.ones(3)}
    for s in (5, 10, 15, 20):
        ckpt.save(str(tmp_path), s, tree)
    assert ckpt.latest_step(str(tmp_path)) == 20
    ckpt.keep_last(str(tmp_path), 2)
    assert ckpt.latest_step(str(tmp_path)) == 20
    assert len(os.listdir(tmp_path)) == 2


def test_auto_resume_identical(tmp_path):
    """Kill after N steps, resume — the final params must match an
    uninterrupted run (deterministic data + ckpt restore)."""
    task = CharLMTask(vocab=32, seed=2)

    def run(steps, ckdir=None, resume=False):
        params = init_params(jax.random.PRNGKey(2), CFG)
        tcfg = TrainConfig(lr=1e-3, steps=steps, log_every=10**9,
                           ckpt_dir=ckdir, ckpt_every=5, clip_norm=0.0)
        return train(lambda p, b: forward_loss(p, b, CFG), params,
                     _batches(task, steps), tcfg, log=lambda *_: None)[0]

    ref = run(10)
    ck = str(tmp_path / "ck")
    run(10, ckdir=ck)          # writes up to step 10
    # simulate crash+restart from step 10's checkpoint, then 5 more steps
    task2 = CharLMTask(vocab=32, seed=2)
    params2 = init_params(jax.random.PRNGKey(2), CFG)
    tcfg = TrainConfig(lr=1e-3, steps=10, log_every=10**9, ckpt_dir=ck,
                       ckpt_every=5, clip_norm=0.0)
    resumed, _ = train(lambda p, b: forward_loss(p, b, CFG), params2,
                       _batches(task2, 10), tcfg, log=lambda *_: None)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(resumed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("opt_fn", [sgd, adamw, adafactor])
def test_optimizers_reduce_quadratic(opt_fn):
    opt = opt_fn()
    params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = opt.update(g, state, params, 0.05)
    assert float(jnp.sum(params["w"] ** 2)) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    c = clip_by_global_norm(g, 1.0)
    n = float(jnp.linalg.norm(c["a"]))
    assert abs(n - 1.0) < 1e-5


def test_prefetcher_order_and_error():
    it = Prefetcher(iter(range(5)), depth=2)
    assert list(it) == [0, 1, 2, 3, 4]

    def bad():
        yield 1
        raise ValueError("boom")

    it = Prefetcher(bad(), depth=2)
    assert next(it) == 1
    with pytest.raises(ValueError):
        next(it)
        next(it)


def test_char_lm_task_deterministic():
    t = CharLMTask(vocab=16, seed=3)
    b1 = t.batch(5, 4, 12)
    b2 = t.batch(5, 4, 12)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].max() < 16
