"""ADMM-CSB training (paper §2.2.2/§3.2) and the progressive controller
(Algorithm 1 outer loop)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    CSBSpec, ProgressivePruner, admm_finalize, admm_init, admm_penalty,
    admm_update, csb_project, density, residual_norm,
)


def test_admm_drives_weights_to_pattern():
    """Minimize ||W - T||^2 with an ADMM-CSB constraint: the finalized
    sparse solution must be near the *optimal* sparse solution (the
    direct projection of T), and the primal residual must shrink."""
    key = jax.random.PRNGKey(0)
    target = jax.random.normal(key, (32, 32))
    spec = CSBSpec(bm=8, bn=8, prune_rate=0.5)
    specs = {"w": spec}
    params = {"w": jnp.zeros((32, 32))}
    state = admm_init(params, specs, rho=2.0)

    def loss(p, st):
        return jnp.sum((p["w"] - target) ** 2) + admm_penalty(p, st, specs)

    lr = 0.05
    res_early = None
    for epoch in range(80):
        for _ in range(10):
            g = jax.grad(loss)(params, state)
            params = jax.tree.map(lambda w, gg: w - lr * gg, params, g)
        state = admm_update(params, state, specs)
        if epoch == 9:
            res_early = float(residual_norm(params, state, specs))
    res = float(residual_norm(params, state, specs))
    # primal residual does not grow (full convergence needs many more
    # epochs; solution quality is asserted below)
    assert res <= res_early * 1.02, (res_early, res)
    final = admm_finalize(params, specs)
    d = float(density(final["w"]))
    assert d <= 0.55
    # finalized weights live exactly on the CSB pattern
    np.testing.assert_array_equal(
        np.asarray(csb_project(final["w"], spec)), np.asarray(final["w"]))
    # solution quality: close to the optimal sparse solution proj(T)
    f_admm = float(jnp.sum((final["w"] - target) ** 2))
    f_opt = float(jnp.sum((csb_project(target, spec) - target) ** 2))
    assert f_admm <= 1.35 * f_opt, (f_admm, f_opt)


def test_admm_penalty_zero_when_converged():
    spec = CSBSpec(bm=8, bn=8, prune_rate=0.5)
    specs = {"w": spec}
    w = csb_project(jax.random.normal(jax.random.PRNGKey(1), (16, 16)), spec)
    params = {"w": w}
    state = admm_init(params, specs)
    assert float(admm_penalty(params, state, specs)) < 1e-9


def test_admm_ignores_unpruned_leaves():
    specs = {"w": CSBSpec(8, 8, 0.5), "b": None}
    params = {"w": jnp.ones((16, 16)), "b": jnp.ones((16,))}
    state = admm_init(params, specs)
    assert state.z["b"] is None
    state2 = admm_update(params, state, specs)
    final = admm_finalize(params, specs)
    np.testing.assert_array_equal(np.asarray(final["b"]), np.ones(16))


class _FakeEval:
    """Lossless iff prune_rate <= threshold — checks the binary search."""

    def __init__(self, threshold):
        self.threshold = threshold
        self.calls = 0

    def __call__(self, rate):
        self.calls += 1
        return rate <= self.threshold + 1e-9


def test_progressive_finds_max_lossless_rate():
    ev = _FakeEval(threshold=0.8125)
    ctl = ProgressivePruner(init_pr=0.25, init_step=0.25)
    while not ctl.done and ev.calls < 60:
        ctl.update(ev(ctl.prune_rate))
    assert ctl.best_lossless_rate <= 0.8125 + 1e-9
    assert ctl.best_lossless_rate >= 0.8125 - 0.25 / 2
    assert ctl.best_compression > 4.0


def test_progressive_monotone_refinement():
    ev = _FakeEval(threshold=0.55)
    ctl = ProgressivePruner(init_pr=0.25, init_step=0.25)
    rates = []
    while not ctl.done and len(rates) < 40:
        rates.append(ctl.prune_rate)
        ctl.update(ev(ctl.prune_rate))
    # never probes below the starting rate
    assert min(rates) >= 0.25 - 1e-9
    assert ctl.best_lossless_rate <= 0.55 + 1e-9


def test_progressive_immediate_failure_recovers():
    """Even if the initial rate fails, the controller backs off."""
    ev = _FakeEval(threshold=0.15)
    ctl = ProgressivePruner(init_pr=0.25, init_step=0.25)
    for _ in range(40):
        if ctl.done:
            break
        ctl.update(ev(ctl.prune_rate))
    assert ctl.prune_rate <= 0.2 or ctl.done
