"""CSB storage format (paper Fig. 3): round-trip, NIO, padded twin."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis — deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (
    CSBMatrix, CSBSpec, csb_masks, csb_project, padded_csb_from_dense,
)
from repro.kernels.ref import densify


def _pruned(rng, shape=(64, 48), bm=16, bn=16, rate=0.6):
    w = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    spec = CSBSpec(bm=bm, bn=bn, prune_rate=rate)
    z = csb_project(w, spec)
    rm, cm = csb_masks(w, spec)
    return np.asarray(z), np.asarray(rm), np.asarray(cm), spec


def test_roundtrip_exact(rng):
    z, rm, cm, spec = _pruned(rng)
    csb = CSBMatrix.from_dense(z, spec.bm, spec.bn, rm, cm)
    np.testing.assert_array_equal(csb.to_dense(), z)


def test_roundtrip_inferred_masks(rng):
    z, *_ = _pruned(rng)
    csb = CSBMatrix.from_dense(z, 16, 16)
    np.testing.assert_array_equal(csb.to_dense(), z)


def test_nio_below_csr(rng):
    z, rm, cm, spec = _pruned(rng, shape=(128, 128), bm=32, bn=32, rate=0.8)
    csb = CSBMatrix.from_dense(z, 32, 32, rm, cm)
    assert csb.nio() < 0.6
    assert CSBMatrix.csr_nio(csb.nnz, 128) > 1.0
    assert csb.nio() < CSBMatrix.csr_nio(csb.nnz, 128)


def test_nio_decays_with_block_size(rng):
    w = jnp.asarray(rng.normal(size=(128, 128)).astype(np.float32))
    nios = []
    for b in (16, 32, 64):
        spec = CSBSpec(bm=b, bn=b, prune_rate=0.75)
        z = np.asarray(csb_project(w, spec))
        rm, cm = [np.asarray(x) for x in csb_masks(w, spec)]
        nios.append(CSBMatrix.from_dense(z, b, b, rm, cm).nio())
    assert nios[0] > nios[1] > nios[2]


def test_padded_matches_dense(rng):
    z, rm, cm, spec = _pruned(rng)
    p = padded_csb_from_dense(z, spec.bm, spec.bn, pad_to=8,
                              row_mask=rm, col_mask=cm)
    np.testing.assert_allclose(np.asarray(densify(p)), z, atol=1e-6)
    assert p.true_flops_per_mvm() <= p.padded_flops_per_mvm()


def test_nonuniform_shape_padding(rng):
    """Matrices not divisible by block size (paper pads SR4's 39-dim)."""
    w = jnp.asarray(rng.normal(size=(37, 23)).astype(np.float32))
    spec = CSBSpec(bm=16, bn=16, prune_rate=0.4)
    z = np.asarray(csb_project(w, spec))
    csb = CSBMatrix.from_dense(z, 16, 16)
    np.testing.assert_array_equal(csb.to_dense(), z)


@settings(max_examples=10, deadline=None)
@given(rate=st.floats(0.3, 0.9), bs=st.sampled_from([8, 16, 32]))
def test_format_roundtrip_property(rate, bs):
    rng = np.random.default_rng(int(rate * 100) + bs)
    z, rm, cm, spec = _pruned(rng, shape=(64, 64), bm=bs, bn=bs, rate=rate)
    csb = CSBMatrix.from_dense(z, bs, bs, rm, cm)
    np.testing.assert_array_equal(csb.to_dense(), z)
    assert csb.nnz == int((z != 0).sum()) or csb.nnz >= int((z != 0).sum())
    p = padded_csb_from_dense(z, bs, bs, row_mask=rm, col_mask=cm)
    np.testing.assert_allclose(np.asarray(densify(p)), z, atol=1e-6)
