"""Speculative decoding with a CSB-pruned self-draft (ISSUE 10).

Acceptance: speculative ``serve_continuous`` is token-for-token
identical to the plain engine at temperature 0 — attn and MLA,
unsharded and on 1x8 / 2x4 host meshes (mesh cases need 8 devices; CI
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=8``) — and
``PagePool.check()`` holds after every rollback (``truncate`` is
monkeypatched to self-check here).

Edge cases pinned below: spec_k=1 degenerates to plain decode, an
all-rejected round still commits the target's token, page-boundary
acceptance rolls the paged cache back without leaking pages, and
temperature>0 sampling is k-invariant under fixed keys (the
token-index-keyed RNG schedule makes spec_k a pure performance knob).
"""
import dataclasses

import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.models import ModelConfig
from repro.models import init_params as lm_init
from repro.serve import (
    EngineConfig, PagePool, Request, derive_draft_params, generate,
    serve_continuous,
)
from repro.serve.speculative import _commit_round

CFG = ModelConfig(name="tiny-spec", mixer="attn", ffn="swiglu",
                  n_layers=2, d_model=32, n_heads=2, n_kv=2, head_dim=16,
                  d_ff=64, vocab=50, dtype="float32", logit_chunk=16,
                  remat=False)
WIN = dataclasses.replace(CFG, name="tiny-spec-win", window=6)
MLA = ModelConfig(name="tiny-spec-mla", mixer="mla", ffn="swiglu",
                  n_layers=2, d_model=32, n_heads=2, n_kv=2, head_dim=16,
                  d_ff=64, vocab=50, kv_lora=16, q_lora=16,
                  rope_head_dim=8, dtype="float32", logit_chunk=16,
                  remat=False)
PAGED = EngineConfig(n_slots=2, paged=True, page_size=4)

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def spec_cfg(base=PAGED, k=3, rate=0.5, **kw):
    return base.replace(speculative=True, spec_k=k,
                        draft_prune_rate=rate, **kw)


@pytest.fixture(scope="module")
def params():
    return lm_init(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def mla_params():
    return lm_init(jax.random.PRNGKey(2), MLA)


def _trace(seed=3, n=6):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    tokens=rng.integers(0, 50, size=int(
                        rng.integers(4, 12))),
                    max_new_tokens=int(rng.integers(3, 9)),
                    arrival=(i // 2) * 2)
            for i in range(n)]


# ---------------------------------------------------------------------------
# the self-draft
# ---------------------------------------------------------------------------

def test_derive_draft_rate0_is_identity(params):
    draft = derive_draft_params(params, 0.0)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(draft)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_derive_draft_prunes_weights_only(params):
    draft = derive_draft_params(params, 0.6)
    flat_p = dict(jax.tree_util.tree_flatten_with_path(params)[0][:0]) \
        or None
    p_leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    d_leaves = jax.tree_util.tree_flatten_with_path(draft)[0]
    pruned = kept = 0
    for (path, pl), (_, dl) in zip(p_leaves, d_leaves):
        name = getattr(path[-1], "key", "")
        pl, dl = np.asarray(pl), np.asarray(dl)
        if pl.ndim in (2, 3) and name.startswith("w"):
            # CSB projection zeroes mass; the surviving entries are the
            # original values
            assert (dl == 0).mean() > 0.2, name
            nz = dl != 0
            np.testing.assert_array_equal(dl[nz], pl[nz])
            pruned += 1
        else:
            np.testing.assert_array_equal(pl, dl)
            kept += 1
    assert pruned > 0 and kept > 0
    del flat_p


# ---------------------------------------------------------------------------
# rejection-sampler unit behavior
# ---------------------------------------------------------------------------

def test_all_rejected_round_still_commits_target_token():
    """Every draft disagrees with the target argmax: the round must
    commit exactly one token — the target's own — so decode always
    progresses regardless of draft quality."""
    v, k = 8, 3
    pi = np.full((k + 1, v), -10.0, np.float32)
    pi[:, 5] = 10.0                      # target argmax is 5 everywhere
    drafts = np.asarray([1, 2, 3])       # never 5
    out = _commit_round(jax.random.PRNGKey(0), rid=0, p=4, drafts=drafts,
                        q_log=pi[:k], pi_log=pi, k_eff=k, temperature=0.0)
    assert out == [5]


def test_full_acceptance_commits_k_plus_bonus():
    v, k = 8, 3
    pi = np.full((k + 1, v), -10.0, np.float32)
    for j, t in enumerate([1, 2, 3, 4]):
        pi[j, t] = 10.0                  # argmax chain 1,2,3 then bonus 4
    out = _commit_round(jax.random.PRNGKey(0), rid=0, p=4,
                        drafts=np.asarray([1, 2, 3]), q_log=pi[:k],
                        pi_log=pi, k_eff=k, temperature=0.0)
    assert out == [1, 2, 3, 4]


def test_k_eff_zero_commits_one_target_token():
    """remaining == 1: no drafts are eligible, the round reduces to one
    target sample (the serve loop's last-token round)."""
    v = 8
    pi = np.full((4, v), -10.0, np.float32)
    pi[0, 6] = 10.0
    out = _commit_round(jax.random.PRNGKey(0), rid=0, p=4,
                        drafts=np.asarray([1, 2, 3]), q_log=pi[:3],
                        pi_log=pi, k_eff=0, temperature=0.0)
    assert out == [6]


# ---------------------------------------------------------------------------
# generate parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg_key", ["attn", "window"])
@pytest.mark.parametrize("k,rate", [(1, 0.0), (1, 0.5), (4, 0.0),
                                    (4, 0.5)])
def test_generate_greedy_parity(params, cfg_key, k, rate):
    cfg = {"attn": CFG, "window": WIN}[cfg_key]
    p = params if cfg is CFG else lm_init(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, 50)
    base = generate(p, cfg, prompt, EngineConfig(max_new_tokens=8))
    spec = generate(p, cfg, prompt,
                    EngineConfig(max_new_tokens=8, speculative=True,
                                 spec_k=k, draft_prune_rate=rate))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(spec))


def test_generate_temperature_k_invariant(params):
    """Fixed-key schedule: with a perfect draft (prune rate 0) the
    committed stream at temperature>0 is the same whatever spec_k is —
    spec_k=1/rate=0 IS the target-only sampler, so this is the
    distributional-parity check as an equality."""
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, 50)
    rng = jax.random.PRNGKey(7)
    outs = [np.asarray(generate(
        params, CFG, prompt,
        EngineConfig(max_new_tokens=10, temperature=0.8,
                     speculative=True, spec_k=k, draft_prune_rate=0.0),
        rng)) for k in (1, 2, 4)]
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


# ---------------------------------------------------------------------------
# serve parity (acceptance)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,rate", [(1, 0.0), (3, 0.0), (3, 0.5)])
def test_serve_spec_matches_plain_attn(params, k, rate):
    reqs = _trace()
    plain = serve_continuous(params, CFG, reqs, PAGED)
    spec = serve_continuous(params, CFG, reqs, spec_cfg(k=k, rate=rate))
    assert spec.tokens == plain.tokens
    st = spec.stats["speculative"]
    assert st["spec_k"] == k and st["rounds"] > 0
    assert 0.0 <= st["acceptance_rate"] <= 1.0
    if rate == 0.0:
        # perfect draft: every eligible proposal must be accepted
        assert st["acceptance_rate"] == 1.0


def test_serve_spec_matches_plain_mla(mla_params):
    reqs = _trace(seed=5)
    plain = serve_continuous(mla_params, MLA, reqs, PAGED)
    spec = serve_continuous(mla_params, MLA, reqs, spec_cfg(k=3, rate=0.3))
    assert spec.tokens == plain.tokens


def test_serve_spec_k1_degenerates_to_plain(params):
    """spec_k=1 with a perfect draft is plain decode wearing the verify
    loop: same tokens, acceptance 1.0, one committed token per proposal
    round plus the bonus."""
    reqs = _trace(seed=9, n=4)
    plain = serve_continuous(params, CFG, reqs, PAGED)
    spec = serve_continuous(params, CFG, reqs, spec_cfg(k=1, rate=0.0))
    assert spec.tokens == plain.tokens
    st = spec.stats["speculative"]
    assert st["acceptance_rate"] == 1.0
    assert st["proposed"] == st["accepted"]


def test_serve_spec_garbage_draft_still_exact(params):
    """Near-total pruning makes the draft useless — acceptance collapses
    but correctness must not: rejection sampling falls back to the
    target's token every round."""
    reqs = _trace(seed=11, n=4)
    plain = serve_continuous(params, CFG, reqs, PAGED)
    spec = serve_continuous(params, CFG, reqs, spec_cfg(k=4, rate=0.9))
    assert spec.tokens == plain.tokens
    st = spec.stats["speculative"]
    assert st["acceptance_rate"] < 1.0


def test_serve_temperature_k_invariant(params):
    reqs = _trace(seed=13)
    key = jax.random.PRNGKey(42)
    runs = [serve_continuous(
        params, CFG, reqs,
        spec_cfg(k=k, rate=0.0, temperature=0.8), rng=key).tokens
        for k in (1, 4)]
    assert runs[0] == runs[1]


@needs8
@pytest.mark.parametrize("shape", [(1, 8), (2, 4)],
                         ids=["mesh1x8", "mesh2x4"])
def test_serve_spec_sharded_matches_unsharded(params, shape):
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(shape),
                ("data", "model"))
    reqs = _trace(seed=6)
    cfg = spec_cfg(k=3, rate=0.3)
    ref = serve_continuous(params, CFG, reqs, cfg)
    res = serve_continuous(params, CFG, reqs, cfg, mesh=mesh)
    assert res.stats["sharded"]
    assert res.tokens == ref.tokens


# ---------------------------------------------------------------------------
# rollback: page-boundary acceptance must not corrupt or leak pages
# ---------------------------------------------------------------------------

def test_rollback_preserves_pool_invariants(params, monkeypatch):
    """Every speculative round ends in a ``PagePool.truncate``; with
    page_size=2 and spec_k=5 the verify span crosses page boundaries
    nearly every round, so rollbacks constantly free tail pages. The
    full allocator oracle (``check()``) must hold after each one — and
    the tokens still match the plain engine exactly."""
    calls = []
    orig = PagePool.truncate

    def checked(self, slot, n_tokens):
        freed = orig(self, slot, n_tokens)
        self.check()
        calls.append(len(freed))
        return freed

    monkeypatch.setattr(PagePool, "truncate", checked)
    reqs = _trace(seed=17, n=6)
    small = EngineConfig(n_slots=2, paged=True, page_size=2)
    plain = serve_continuous(params, CFG, reqs, small)
    spec = serve_continuous(
        params, CFG, reqs,
        small.replace(speculative=True, spec_k=5, draft_prune_rate=0.6))
    assert spec.tokens == plain.tokens
    assert calls, "speculative serve never truncated"
    assert sum(calls) > 0, "no rollback ever freed a page"


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------

def test_spec_serve_requires_paged(params):
    with pytest.raises(ValueError, match="paged=True"):
        serve_continuous(params, CFG, _trace(n=2),
                         EngineConfig(n_slots=2, speculative=True))


def test_spec_rejects_stateful_mixer():
    hyb = ModelConfig(name="tiny-spec-hyb", family="hybrid",
                      mixer="hybrid", ffn="swiglu", n_layers=2,
                      d_model=32, n_heads=2, n_kv=2, head_dim=16,
                      d_ff=64, vocab=50, d_state=8, ssd_headdim=16,
                      ssd_chunk=4, ssd_expand=2, conv_k=4,
                      dtype="float32", logit_chunk=16, remat=False)
    p = lm_init(jax.random.PRNGKey(1), hyb)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0, 50)
    with pytest.raises(NotImplementedError, match="per-position"):
        generate(p, hyb, prompt,
                 EngineConfig(max_new_tokens=4, speculative=True))


def test_spec_empty_requests(params):
    res = serve_continuous(params, CFG, [], spec_cfg())
    assert res.tokens == {}
    assert res.stats["speculative"]["rounds"] == 0
