"""Continuous-batching serve: slot lifecycle, admission, sharded parity.

Device-parity tests for the sharded paths need 8 host devices (CI sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8``); without them
they skip. Scheduler and diff-gate tests are host-only and always run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.models import ModelConfig, decode_step, init_cache
from repro.models import init_params as lm_init
from repro.serve import (
    EngineConfig, Request, SlotScheduler, cache_len_of, generate,
    grow_cache, serve_continuous, simulate_admission,
)

CFG = ModelConfig(name="tiny", mixer="attn", ffn="swiglu", n_layers=2,
                  d_model=32, n_heads=2, n_kv=2, head_dim=16, d_ff=64,
                  vocab=50, dtype="float32", logit_chunk=16, remat=False)

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def params():
    return lm_init(jax.random.PRNGKey(0), CFG)


def _requests(prompts, max_new, arrivals=None):
    arrivals = arrivals or [0] * len(prompts)
    return [Request(rid=i, tokens=np.asarray(p), max_new_tokens=m,
                    arrival=a)
            for i, (p, m, a) in enumerate(zip(prompts, max_new, arrivals))]


def _ref_tokens(params, prompt, n_new):
    """Generated tail of a solo fixed-batch greedy run."""
    out = generate(params, CFG, jnp.asarray(prompt)[None],
                   EngineConfig(max_new_tokens=n_new))
    return np.asarray(out)[0, len(prompt):]


# ---------------------------------------------------------------------------
# cache time-dim helpers (host + tiny device work)
# ---------------------------------------------------------------------------

def test_grow_cache_empty_and_ragged():
    assert grow_cache({}, 4) == {}
    # pure-state cache (SSD): no time-keyed leaves -> untouched
    ssd = {"conv": jnp.zeros((2, 1, 3, 8)), "ssm": jnp.zeros((2, 1, 4, 4))}
    grown = grow_cache(ssd, 5)
    assert jax.tree.map(lambda a: a.shape, grown) == \
        jax.tree.map(lambda a: a.shape, ssd)
    assert cache_len_of(ssd) == 0
    # ragged hybrid cache: attn leaves grow, ssd leaves don't
    hyb = {"attn": {"k": jnp.ones((2, 1, 3, 2, 4)),
                    "v": jnp.ones((2, 1, 3, 2, 4))},
           "ssd": ssd}
    grown = grow_cache(hyb, 2)
    assert grown["attn"]["k"].shape == (2, 1, 5, 2, 4)
    assert grown["ssd"]["conv"].shape == ssd["conv"].shape
    # grown region is zero-padded, original values intact
    np.testing.assert_array_equal(np.asarray(grown["attn"]["k"][:, :, :3]),
                                  1.0)
    np.testing.assert_array_equal(np.asarray(grown["attn"]["k"][:, :, 3:]),
                                  0.0)
    # zero-length time dim grows from empty
    empty_t = {"k": jnp.zeros((1, 1, 0, 2, 4))}
    assert grow_cache(empty_t, 3)["k"].shape == (1, 1, 3, 2, 4)
    # non-positive growth is the identity
    assert grow_cache(hyb, 0) is hyb
    assert grow_cache(hyb, -2) is hyb


# ---------------------------------------------------------------------------
# scheduler (host only)
# ---------------------------------------------------------------------------

def test_scheduler_admission_queues_beyond_slots():
    reqs = [Request(rid=i, tokens=np.zeros(2, np.int32), max_new_tokens=3)
            for i in range(5)]
    sched = SlotScheduler(2)
    for r in reqs:
        sched.submit(r)
    first = sched.admit()
    assert [r.rid for _, r in first] == [0, 1]
    assert sched.admit() == []          # both slots busy now
    for slot, _ in first:
        assert sched.started(slot, 7)
    # run both to completion; freed slots must readmit FIFO
    freed = []
    while not freed:
        freed = sched.advance(np.zeros(2, np.int64))
    nxt = sched.admit()
    assert [r.rid for _, r in nxt] == [2, 3]


def test_scheduler_occupancy_and_idle():
    # uniform trace fills every slot-step
    uni = [Request(rid=i, tokens=np.zeros(1, np.int32), max_new_tokens=4)
           for i in range(4)]
    sim = simulate_admission(2, uni)
    assert sim["occupancy"] == 1.0
    assert sim["decode_steps"] == 6     # 2 waves x 3 decode steps
    assert sim["generated_tokens"] == 16
    # a gap in arrivals idles the clock, not the decode accounting
    gap = [Request(rid=0, tokens=np.zeros(1, np.int32), max_new_tokens=2),
           Request(rid=1, tokens=np.zeros(1, np.int32), max_new_tokens=2,
                   arrival=50)]
    sim = simulate_admission(2, gap)
    assert sim["idle_steps"] > 0
    assert sim["occupancy"] == 0.5      # one slot of two ever busy
    # single-token requests finish off the prefill, no decode at all
    one = [Request(rid=0, tokens=np.zeros(1, np.int32), max_new_tokens=1)]
    sim = simulate_admission(1, one)
    assert sim["decode_steps"] == 0 and sim["generated_tokens"] == 1


def test_scheduler_errors():
    with pytest.raises(ValueError):
        SlotScheduler(0)
    with pytest.raises(ValueError):
        SlotScheduler(1).submit(
            Request(rid=0, tokens=np.zeros(1, np.int32), max_new_tokens=0))


# ---------------------------------------------------------------------------
# decode-step per-slot positions
# ---------------------------------------------------------------------------

def test_vector_pos_matches_scalar(params):
    toks = jax.random.randint(jax.random.PRNGKey(2), (3, 1), 0, 50)
    cache = init_cache(CFG, 3, 8, jnp.float32)
    lg_s, c_s = decode_step(params, cache, toks, 4, CFG)
    lg_v, c_v = decode_step(params, cache, toks,
                            jnp.full((3,), 4, jnp.int32), CFG)
    np.testing.assert_allclose(np.asarray(lg_v), np.asarray(lg_s),
                               rtol=1e-6, atol=1e-6)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6), c_v, c_s)


# ---------------------------------------------------------------------------
# slot lifecycle through the real engine
# ---------------------------------------------------------------------------

def test_evict_refill_single_slot_no_leak(params):
    """Two very different requests forced through the SAME slot one
    after the other: each must decode exactly as it does alone (no KV
    or state of request 0 survives into request 1)."""
    rng = np.random.default_rng(3)
    p0 = rng.integers(0, 50, size=9)
    p1 = rng.integers(0, 50, size=4)
    res = serve_continuous(params, CFG, _requests([p0, p1], [5, 6]),
                           EngineConfig(n_slots=1))
    assert res.stats["requests"] == 2
    np.testing.assert_array_equal(res.tokens[0], _ref_tokens(params, p0, 5))
    np.testing.assert_array_equal(res.tokens[1], _ref_tokens(params, p1, 6))


def test_continuous_matches_generate_batch(params):
    """Same-length prompts admitted together == fixed-batch generate,
    token for token."""
    prompts = np.asarray(
        jax.random.randint(jax.random.PRNGKey(4), (3, 6), 0, 50))
    ref = np.asarray(generate(params, CFG, jnp.asarray(prompts),
                              EngineConfig(max_new_tokens=5)))[:, 6:]
    res = serve_continuous(
        params, CFG, _requests(list(prompts), [5, 5, 5]),
        EngineConfig(n_slots=3))
    for i in range(3):
        np.testing.assert_array_equal(res.tokens[i], ref[i])
    assert res.stats["occupancy"] == 1.0


def test_continuous_mixed_lengths_and_arrivals(params):
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, 50, size=n) for n in (4, 8, 5, 7, 6)]
    max_new = [4, 6, 5, 4, 6]
    reqs = _requests(prompts, max_new, arrivals=[0, 0, 3, 6, 6])
    res = serve_continuous(params, CFG, reqs, EngineConfig(n_slots=2))
    for i, p in enumerate(prompts):
        np.testing.assert_array_equal(
            res.tokens[i], _ref_tokens(params, p, max_new[i]),
            err_msg=f"request {i}")
    st = res.stats
    assert st["prefills"] == 5 and 0.0 < st["occupancy"] <= 1.0


def test_continuous_rejects_undersized_cache(params):
    reqs = _requests([np.zeros(6, np.int64)], [8])
    with pytest.raises(ValueError):
        serve_continuous(params, CFG, reqs,
                         EngineConfig(n_slots=1, cache_len=10))


# ---------------------------------------------------------------------------
# sharded parity (8 host devices)
# ---------------------------------------------------------------------------

@needs8
@pytest.mark.parametrize("shape", [(1, 8), (2, 4)],
                         ids=["mesh1x8", "mesh2x4"])
def test_continuous_sharded_matches_unsharded(params, shape):
    """Acceptance: sharded continuous-batching generate == unsharded
    greedy output token-for-token on 1x8 and 2x4 host meshes."""
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(shape),
                ("data", "model"))
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, 50, size=n) for n in (5, 9, 6, 7)]
    max_new = [5, 4, 6, 5]
    reqs = _requests(prompts, max_new, arrivals=[0, 0, 2, 4])
    res = serve_continuous(params, CFG, reqs, EngineConfig(n_slots=2),
                           mesh=mesh)
    assert res.stats["sharded"]
    for i, p in enumerate(prompts):
        np.testing.assert_array_equal(
            res.tokens[i], _ref_tokens(params, p, max_new[i]),
            err_msg=f"mesh {shape} request {i}")


@needs8
def test_rnn_frames_sharded_matches_local(rng):
    """Frame serving with CSB weights: partitioned over the model axis
    + data-sharded batch == the local Pallas kernel."""
    from repro.cells import init_params as cell_init, make_cell
    from repro.core import (
        CSBSpec, csb_masks, csb_project, padded_csb_from_dense,
    )
    from repro.serve import rnn_serve_frames

    cell = make_cell("gru", 16, 32)
    wparams = cell_init(cell, jax.random.PRNGKey(8))
    spec = CSBSpec(bm=8, bn=8, prune_rate=0.5)
    csb = {}
    for k, w in wparams.items():
        if w.ndim == 2:
            z = csb_project(w, spec)
            rm, cm = csb_masks(w, spec)
            csb[k] = padded_csb_from_dense(
                np.asarray(z), 8, 8, row_mask=np.asarray(rm),
                col_mask=np.asarray(cm))
        else:
            csb[k] = w
    frames = jnp.asarray(rng.normal(size=(4, 2, 16)).astype(np.float32))
    outs, _, _ = rnn_serve_frames(cell, csb, frames, warmup=1)
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4),
                ("data", "model"))
    outs_sh, _, us = rnn_serve_frames(cell, csb, frames, warmup=1,
                                      mesh=mesh)
    np.testing.assert_allclose(np.asarray(outs_sh), np.asarray(outs),
                               rtol=2e-5, atol=2e-5)
    assert us > 0


@needs8
def test_generate_sharded_matches_unsharded(params):
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4),
                ("data", "model"))
    prompt = jax.random.randint(jax.random.PRNGKey(7), (4, 6), 0, 50)
    scfg = EngineConfig(max_new_tokens=5)
    ref = generate(params, CFG, prompt, scfg)
    out = generate(params, CFG, prompt, scfg, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# serve rows gate in benchmarks/diff.py
# ---------------------------------------------------------------------------

def _rec(name, rows, calib=100.0):
    return {name: {"bench": name, "calib_us": calib,
                   "rows": [{"name": n, "us_per_call": us, "derived": d}
                            for n, us, d in rows]}}


def test_diff_gates_serve_rows():
    from benchmarks.diff import diff_records, parse_gate_rows

    assert parse_gate_rows("kernel:/mvm,serve:/us_per") == \
        {"kernel": ("/mvm",), "serve": ("/us_per",)}
    assert parse_gate_rows("/mvm") == {"*": ("/mvm",)}
    assert parse_gate_rows("kernel:/mvm|paged_attn/decode") == \
        {"kernel": ("/mvm", "paged_attn/decode")}

    base = _rec("serve", [
        ("serve/continuous/us_per_token", 1000.0, 100.0),
        ("serve/frames/us_per_frame", 2000.0, "x"),
        ("serve/continuous/occupancy", 0.0, 0.9),
    ])
    fresh = _rec("serve", [
        ("serve/continuous/us_per_token", 1500.0, 66.0),   # 1.5x: fails
        ("serve/frames/us_per_frame", 2100.0, "x"),        # 1.05x: ok
        ("serve/continuous/occupancy", 0.0, 0.4),          # never gates
    ])
    _, failures = diff_records(fresh, base, 0.25, {"serve"}, 50.0)
    assert len(failures) == 1 and "us_per_token" in failures[0]

    # same 1.5x regression passes when the serve table is not gated
    _, failures = diff_records(fresh, base, 0.25, {"kernel"}, 50.0)
    assert failures == []

    # tokens/sec collapse == us/token rise: the one rule covers both
    ok = _rec("serve", [("serve/continuous/us_per_token", 1100.0, 91.0)])
    _, failures = diff_records(ok, base, 0.25, {"serve"}, 50.0)
    assert failures == []
