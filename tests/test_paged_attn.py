"""Pallas paged-attention decode kernel: parity with the gather path.

Acceptance (ISSUE 6): ``use_kernel=True`` decode through the paged pool
is token-for-token / numerically equal to the ``paged_gather`` fallback
for attn, windowed attn, MLA and hybrid mixers, with scalar and (B,)
vector positions, unsharded and on 1x8 / 2x4 host meshes — and the
kernel path's jaxpr no longer contains the materialized
``(B, max_pages*P)`` gather the fallback builds before every step.

Also here: edge-case coverage for the paged-cache primitives
(``paged_write`` / ``paged_gather``) — scratch-page routing for
inactive slots, vector-pos writes straddling page boundaries,
``max_pages=1`` pools — and the (once-xfail, now asserting) sharded
hybrid decode parity check on the 2x4 mesh.
"""
import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.kernels import paged_attn_decode
from repro.models import (
    ModelConfig, decode_step_paged, init_paged_cache,
)
from repro.models import init_params as lm_init
from repro.models import layers as L
from repro.serve import (
    EngineConfig, PagePool, Request, generate, serve_continuous,
)

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

ATTN = ModelConfig(name="tiny-pa-attn", mixer="attn", ffn="swiglu",
                   n_layers=2, d_model=32, n_heads=4, n_kv=2, head_dim=16,
                   d_ff=64, vocab=50, dtype="float32", logit_chunk=16,
                   remat=False)
WIN = dataclasses.replace(ATTN, name="tiny-pa-win", window=6)
MLA = ModelConfig(name="tiny-pa-mla", mixer="mla", ffn="swiglu",
                  n_layers=2, d_model=32, n_heads=2, n_kv=2, head_dim=16,
                  d_ff=64, vocab=50, kv_lora=16, q_lora=16,
                  rope_head_dim=8, dtype="float32", logit_chunk=16,
                  remat=False)
HYB = ModelConfig(name="tiny-pa-hyb", family="hybrid", mixer="hybrid",
                  ffn="swiglu", n_layers=2, d_model=32, n_heads=2,
                  n_kv=2, head_dim=16, d_ff=64, vocab=50, d_state=8,
                  ssd_headdim=16, ssd_chunk=4, ssd_expand=2, conv_k=4,
                  dtype="float32", logit_chunk=16, remat=False)


def _randomized(tree, seed=0):
    """Fill floating leaves with deterministic garbage so masked-out
    pool positions are non-trivial in both paths."""
    return jax.tree.map(
        lambda a: jax.random.normal(
            jax.random.PRNGKey((a.size + seed) % 97), a.shape
        ).astype(a.dtype)
        if jnp.issubdtype(a.dtype, jnp.floating) else a, tree)


def _pool_and_cache(cfg, pos_list, psz=4, n_pages=10, max_pages=3,
                    seed=0):
    n_slots = len(pos_list)
    pool = PagePool(psz, n_pages, n_slots, max_pages)
    for s, p in enumerate(pos_list):
        pool.reserve(s, max_pages * psz)
        pool.ensure(s, int(p) + 1)
    cache = _randomized(
        init_paged_cache(cfg, n_pages, psz, n_slots, jnp.float32), seed)
    return cache, pool.device_table()


# ---------------------------------------------------------------------------
# full decode-step parity: kernel vs gather, all mixers, both pos forms
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("vec", [False, True],
                         ids=["scalar-pos", "vector-pos"])
@pytest.mark.parametrize("cfg", [ATTN, WIN, MLA, HYB],
                         ids=lambda c: c.name)
def test_decode_step_kernel_matches_gather(cfg, vec):
    pos_list = [7, 2, 10] if vec else [7, 7, 7]
    pos = jnp.asarray(pos_list, jnp.int32) if vec else 7
    cache, table = _pool_and_cache(cfg, pos_list)
    params = lm_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (3, 1), 0, cfg.vocab)
    lg_g, c_g = decode_step_paged(params, cache, toks, pos, table, cfg)
    lg_k, c_k = decode_step_paged(params, cache, toks, pos, table, cfg,
                                  use_kernel=True)
    np.testing.assert_allclose(np.asarray(lg_k), np.asarray(lg_g),
                               rtol=2e-5, atol=2e-5)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5), c_k, c_g)


# ---------------------------------------------------------------------------
# direct kernel parity on primitive edge shapes
# ---------------------------------------------------------------------------

def _ref_paged_attn(q, kp, vp, table, pos, scale, window=None,
                    q2=None, k2p=None):
    """The gather-path attention math (layers.attn_decode_paged body),
    as an oracle for direct kernel calls."""
    b, h, d = q.shape
    kg = L.paged_gather(kp, table)
    vg = L.paged_gather(vp, table)
    t, kv = kg.shape[1], kg.shape[2]
    rep = h // kv
    qh = q.reshape(b, kv, rep, d)
    sc = jnp.einsum("bgrd,bkgd->bgrk", qh.astype(kg.dtype), kg,
                    preferred_element_type=jnp.float32)
    if q2 is not None:
        k2g = L.paged_gather(k2p, table)
        sc = sc + jnp.einsum(
            "bgrd,bkgd->bgrk", q2.reshape(b, kv, rep, -1).astype(
                k2g.dtype), k2g, preferred_element_type=jnp.float32)
    row = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    kpos = jnp.arange(t)
    mask = kpos[None, :] <= row[:, None]
    if window is not None:
        mask &= kpos[None, :] > row[:, None] - window
    sc = jnp.where(mask[:, None, None, :], sc * scale, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bgrk,bkgd->bgrd", p.astype(vg.dtype), vg,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, h, -1)


def test_kernel_vector_pos_at_page_boundaries(rng):
    """Slots sitting at psz-1 / psz / 2*psz-1 (last offset of a page,
    first of the next, last of the last page) must mask exactly."""
    psz, kv, d = 4, 2, 8
    pool_shape = (9, psz, kv, d)            # 8 pages + scratch
    kp = jnp.asarray(rng.normal(size=pool_shape), jnp.float32)
    vp = jnp.asarray(rng.normal(size=pool_shape), jnp.float32)
    table = jnp.asarray([[0, 1], [2, 3], [5, 6]], jnp.int32)
    pos = jnp.asarray([psz - 1, psz, 2 * psz - 1], jnp.int32)
    q = jnp.asarray(rng.normal(size=(3, 4, d)), jnp.float32)
    out = paged_attn_decode(q, kp, vp, table, pos,
                            scale=1.0 / math.sqrt(d))
    ref = _ref_paged_attn(q, kp, vp, table, pos, 1.0 / math.sqrt(d))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_kernel_max_pages_one_pool(rng):
    """max_pages=1: the smallest legal table still walks correctly."""
    psz, kv, d = 8, 1, 16
    kp = jnp.asarray(rng.normal(size=(4, psz, kv, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(4, psz, kv, d)), jnp.float32)
    table = jnp.asarray([[2], [0], [3]], jnp.int32)
    for pos in (0, jnp.asarray([3, 0, psz - 1], jnp.int32)):
        q = jnp.asarray(rng.normal(size=(3, 2, d)), jnp.float32)
        out = paged_attn_decode(q, kp, vp, table, pos,
                                scale=1.0 / math.sqrt(d))
        ref = _ref_paged_attn(q, kp, vp, table, pos, 1.0 / math.sqrt(d))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)


def test_kernel_inactive_slot_scratch_page(rng):
    """A slot whose table row is all scratch (inactive) still produces
    finite output — the mask kills every scratch position except
    kpos=0..pos, which read scratch garbage identically to the gather
    path."""
    psz, kv, d = 4, 2, 8
    kp = jnp.asarray(rng.normal(size=(5, psz, kv, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(5, psz, kv, d)), jnp.float32)
    scratch = 4
    table = jnp.asarray([[0, 1], [scratch, scratch]], jnp.int32)
    q = jnp.asarray(rng.normal(size=(2, 4, d)), jnp.float32)
    pos = jnp.asarray([6, 0], jnp.int32)
    out = paged_attn_decode(q, kp, vp, table, pos,
                            scale=1.0 / math.sqrt(d))
    ref = _ref_paged_attn(q, kp, vp, table, pos, 1.0 / math.sqrt(d))
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# paged_write / paged_gather primitive edge cases (satellite)
# ---------------------------------------------------------------------------

def test_paged_write_vector_pos_page_boundaries():
    psz = 4
    pool = jnp.zeros((9, psz, 2), jnp.float32)   # 8 pages + scratch
    table = jnp.asarray([[0, 1], [2, 3], [5, 6]], jnp.int32)
    pos = jnp.asarray([psz - 1, psz, 2 * psz - 1], jnp.int32)
    new = jnp.arange(1, 7, dtype=jnp.float32).reshape(3, 1, 2)
    out = np.asarray(L.paged_write(pool, new, pos, table))
    # (slot, phys page, offset): 3 -> (0,3); 4 -> (3,0); 7 -> (6,3)
    np.testing.assert_array_equal(out[0, 3], [1, 2])
    np.testing.assert_array_equal(out[3, 0], [3, 4])
    np.testing.assert_array_equal(out[6, 3], [5, 6])
    assert np.count_nonzero(out) == 6            # nothing else touched


def test_paged_write_inactive_slots_hit_scratch_page():
    """Inactive slots (table row = scratch) write into the scratch page
    and never corrupt an allocatable page."""
    psz, n_slots = 4, 3
    pool = PagePool(psz, 6, n_slots, 2)
    pool.reserve(1, 5)
    pool.ensure(1, 1)
    table = pool.device_table()
    assert pool.scratch_page == 6
    # rows 0 and 2 never reserved: all-scratch
    np.testing.assert_array_equal(np.asarray(table)[0], [6, 6])
    np.testing.assert_array_equal(np.asarray(table)[2], [6, 6])
    dev = jnp.zeros((7, psz, 2), jnp.float32)
    new = jnp.arange(1, 7, dtype=jnp.float32).reshape(3, 1, 2)
    out = np.asarray(L.paged_write(dev, new, 0, table))
    live = pool.slot_pages(1)[0]
    np.testing.assert_array_equal(out[live, 0], [3, 4])
    # every other allocatable page is untouched
    untouched = [p for p in range(6) if p != live]
    assert not np.count_nonzero(out[untouched])
    # both inactive writes landed on the scratch page (either may win)
    assert out[6, 0].tolist() in ([1, 2], [5, 6])


def test_paged_gather_max_pages_one(rng):
    pool = jnp.asarray(rng.normal(size=(4, 8, 3)), jnp.float32)
    table = jnp.asarray([[2], [0], [3]], jnp.int32)
    g = L.paged_gather(pool, table)
    assert g.shape == (3, 8, 3)
    np.testing.assert_array_equal(np.asarray(g),
                                  np.asarray(pool)[np.asarray(table)[:, 0]])


def test_paged_gather_scratch_rows_masked_by_position():
    """Scratch-page garbage gathered for inactive slots sits at logical
    positions the kpos<=pos mask excludes — write then gather round-trips
    only the live extent."""
    psz = 4
    pool = PagePool(psz, 4, 2, 2)
    pool.reserve(0, 6)
    pool.ensure(0, 6)
    table = pool.device_table()
    dev = jnp.full((5, psz, 1), 7.0, jnp.float32)   # garbage everywhere
    for t in range(6):
        dev = L.paged_write(dev, jnp.full((2, 1, 1), float(t)),
                            t, table)
    g = np.asarray(L.paged_gather(dev, table))      # (2, 8, 1)
    np.testing.assert_array_equal(g[0, :6, 0], np.arange(6.0))
    # slot 1 is inactive: every gathered row is the scratch page — the
    # decode mask (pos<0 ... none attendable) is what protects it, not
    # the gather; assert it reads the scratch page verbatim
    np.testing.assert_array_equal(g[1, :psz], np.asarray(dev)[4])
    np.testing.assert_array_equal(g[1, psz:], np.asarray(dev)[4])


# ---------------------------------------------------------------------------
# the point of the kernel: no (B, max_pages*P) gather in the jaxpr
# ---------------------------------------------------------------------------

def _collect_shapes(jaxpr, acc):
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            if hasattr(v.aval, "shape"):
                acc.add(tuple(v.aval.shape))
        for val in eqn.params.values():
            for sub in jax.tree.leaves(
                    val, is_leaf=lambda x: hasattr(x, "eqns")
                    or hasattr(x, "jaxpr")):
                if hasattr(sub, "jaxpr"):
                    sub = sub.jaxpr
                if hasattr(sub, "eqns"):
                    _collect_shapes(sub, acc)
    return acc


def _decode_step_shapes(use_kernel):
    n_slots, psz, mp = 3, 4, 6
    pool = PagePool(psz, 8, n_slots, mp)
    for s in range(n_slots):
        pool.reserve(s, 8)
        pool.ensure(s, 5)
    cache = init_paged_cache(ATTN, 8, psz, n_slots, jnp.float32)
    params = lm_init(jax.random.PRNGKey(0), ATTN)
    toks = jnp.zeros((n_slots, 1), jnp.int32)
    fn = functools.partial(decode_step_paged, cfg=ATTN,
                           use_kernel=use_kernel)
    closed = jax.make_jaxpr(fn)(params, cache, toks, 4,
                                pool.device_table())
    return _collect_shapes(closed.jaxpr, set())


def test_kernel_path_never_materializes_the_gather():
    """The fallback trace contains (B, max_pages*P, ...) intermediates
    (the HBM gather); the kernel trace must not — that's the
    memory-traffic win the bench row measures."""
    b, t = 3, 24                               # B=3 slots, 6 pages * 4
    gathered = {s for s in _decode_step_shapes(False)
                if len(s) >= 2 and s[0] == b and s[1] == t}
    assert gathered, "gather path no longer materializes — update test"
    kernel = {s for s in _decode_step_shapes(True)
              if len(s) >= 2 and s[0] == b and s[1] == t}
    assert not kernel, f"kernel path still materializes {kernel}"


# ---------------------------------------------------------------------------
# serve-level parity: unsharded + 1x8 / 2x4 meshes
# ---------------------------------------------------------------------------

def _requests(prompts, max_new, arrivals=None):
    arrivals = arrivals or [0] * len(prompts)
    return [Request(rid=i, tokens=np.asarray(p), max_new_tokens=m,
                    arrival=a)
            for i, (p, m, a) in enumerate(zip(prompts, max_new, arrivals))]


def _ref_tokens(params, cfg, prompt, n_new):
    out = generate(params, cfg, jnp.asarray(prompt)[None],
                   EngineConfig(max_new_tokens=n_new))
    return np.asarray(out)[0, len(prompt):]


@pytest.mark.parametrize("cfg", [ATTN, MLA, HYB], ids=lambda c: c.name)
def test_serve_kernel_matches_generate(cfg):
    params = lm_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, size=n) for n in (4, 8, 5)]
    max_new = [4, 6, 5]
    reqs = _requests(prompts, max_new, arrivals=[0, 0, 3])
    res = serve_continuous(params, cfg, reqs,
                           EngineConfig(n_slots=2, paged=True, page_size=4,
                                        use_kernel=True))
    assert res.stats["paged"]
    for i, p in enumerate(prompts):
        np.testing.assert_array_equal(
            res.tokens[i], _ref_tokens(params, cfg, p, max_new[i]),
            err_msg=f"{cfg.name} request {i}")


@needs8
@pytest.mark.parametrize("shape", [(1, 8), (2, 4)],
                         ids=["mesh1x8", "mesh2x4"])
def test_serve_kernel_sharded_matches_unsharded(shape):
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(shape),
                ("data", "model"))
    params = lm_init(jax.random.PRNGKey(0), ATTN)
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, ATTN.vocab, size=n) for n in (5, 9, 6)]
    max_new = [5, 4, 6]
    reqs = _requests(prompts, max_new, arrivals=[0, 0, 2])
    res = serve_continuous(params, ATTN, reqs,
                           EngineConfig(n_slots=2, paged=True, page_size=4,
                                        use_kernel=True), mesh=mesh)
    assert res.stats["sharded"] and res.stats["paged"]
    for i, p in enumerate(prompts):
        np.testing.assert_array_equal(
            res.tokens[i], _ref_tokens(params, ATTN, p, max_new[i]),
            err_msg=f"mesh {shape} request {i}")


@needs8
@pytest.mark.parametrize("shape", [(1, 8), (2, 4)],
                         ids=["mesh1x8", "mesh2x4"])
def test_serve_kernel_sharded_mla_matches_gather_path(shape):
    """MLA kernel vs gather fallback on the SAME mesh: token-identical.

    Sharded MLA *decode itself* drifts from the unsharded trace on tiny
    host-mesh configs (pre-existing, paging- and kernel-independent —
    even contiguous ``generate`` with a mesh shows it), so the kernel
    acceptance bar for MLA is fallback-relative: whatever the sharded
    gather path produces, the kernel must reproduce. For plain attn the
    kernel meets the *stronger* unsharded-reference bar (test above);
    its replicated pallas boundary sidesteps the GSPMD remat hazard
    that can flip the gather fallback's sampled ties."""
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(shape),
                ("data", "model"))
    params = lm_init(jax.random.PRNGKey(0), MLA)
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, MLA.vocab, size=n) for n in (5, 9, 6)]
    max_new = [5, 4, 6]
    ker = serve_continuous(params, MLA,
                           _requests(prompts, max_new, arrivals=[0, 0, 2]),
                           EngineConfig(n_slots=2, paged=True, page_size=4,
                                        use_kernel=True), mesh=mesh)
    ref = serve_continuous(params, MLA,
                           _requests(prompts, max_new, arrivals=[0, 0, 2]),
                           EngineConfig(n_slots=2, paged=True,
                                        page_size=4), mesh=mesh)
    assert ker.stats["sharded"] and ker.stats["paged"]
    for i in range(len(prompts)):
        np.testing.assert_array_equal(
            ker.tokens[i], ref.tokens[i],
            err_msg=f"mesh {shape} request {i}")


# ---------------------------------------------------------------------------
# sharded hybrid decode parity on the 2x4 mesh (was an xfail drift repro
# since PR 4; root cause was never tie-flips but unanchored GSPMD layout
# propagation — the in-proj / conv-weight / row-parallel-wo shardings
# leaked into the SSD chunked scan and the decode softmax chain, hitting
# XLA's involuntary-full-rematerialization transition that miscompiles
# on the CPU SPMD backend. Fixed by the "ssd_inner" / "residual" anchors
# in models.layers + models.lm; see docs/known-issues.md)
# ---------------------------------------------------------------------------

@needs8
def test_hybrid_sharded_decode_drift_2x4():
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4),
                ("data", "model"))
    params = lm_init(jax.random.PRNGKey(0), HYB)
    rng = np.random.default_rng(13)
    prompt = jnp.asarray(rng.integers(0, 50, size=7))[None]
    scfg = EngineConfig(max_new_tokens=12)
    ref = np.asarray(generate(params, HYB, prompt, scfg))[0]
    shr = np.asarray(generate(params, HYB, prompt, scfg, mesh=mesh))[0]
    div = np.nonzero(ref != shr)[0]
    first = int(div[0]) if div.size else -1
    np.testing.assert_array_equal(
        shr, ref,
        err_msg=f"sharded hybrid decode diverges at token index {first}")
