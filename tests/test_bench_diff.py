"""benchmarks/diff.py gate edges.

The CI perf gate must fail ONLY on a genuine regression in a gated row:
tables/rows missing on either side, informational (us_per_call = 0,
``derived``-only) rows, and calibration blips must never trip it. The
paged serve row is gated like every other ``serve:/us_per`` row — an
injected 1.5x regression must fail, and must keep failing when it hides
behind a favorable calibration misread (the min(raw, norm) rule).
"""
import pytest

from benchmarks.diff import diff_records


def _rec(name, rows, calib=100.0):
    return {name: {"bench": name, "calib_us": calib,
                   "rows": [{"name": n, "us_per_call": us, "derived": d}
                            for n, us, d in rows]}}


BASE = _rec("serve", [
    ("serve/paged/us_per_token", 1000.0, 100.0),
    ("serve/continuous/us_per_token", 900.0, 110.0),
    ("serve/paged/peak_cache_tokens", 0.0, "paged=96;contiguous=256"),
])


def test_missing_baseline_table_is_informational():
    fresh = _rec("serve", [("serve/paged/us_per_token", 9000.0, 11.0)])
    lines, failures = diff_records(fresh, {}, 0.25, {"serve"}, 50.0)
    assert failures == []
    assert any("[new]" in ln and "serve" in ln for ln in lines)


def test_baseline_table_without_fresh_run_is_informational():
    lines, failures = diff_records({}, BASE, 0.25, {"serve"}, 50.0)
    assert failures == []
    assert any("[missing]" in ln for ln in lines)


def test_fresh_row_absent_from_baseline_never_gates():
    """A brand-new gated-pattern row (no baseline) reports as [new] and
    a vanished row as [gone]; neither fails the gate."""
    fresh = _rec("serve", [
        ("serve/paged/us_per_token", 1000.0, 100.0),
        ("serve/paged_v2/us_per_token", 99999.0, 1.0),   # new, huge: ok
    ])
    lines, failures = diff_records(fresh, BASE, 0.25, {"serve"}, 50.0)
    assert failures == []
    assert any("[new] serve/paged_v2/us_per_token" in ln for ln in lines)
    assert any("[gone] serve/continuous/us_per_token" in ln
               for ln in lines)


def test_derived_only_rows_report_but_never_gate():
    """us_per_call == 0 rows (occupancy, memory footprint) carry their
    payload in ``derived``; numeric drift is reported, string payloads
    and any size of drift never fail CI."""
    base = _rec("serve", [
        ("serve/paged/peak_cache_tokens", 0.0, "paged=96;contiguous=256"),
        ("serve/continuous/occupancy", 0.0, 0.9),
    ])
    fresh = _rec("serve", [
        ("serve/paged/peak_cache_tokens", 0.0, "paged=200;contiguous=256"),
        ("serve/continuous/occupancy", 0.0, 0.3),        # 3x collapse
    ])
    lines, failures = diff_records(fresh, base, 0.25, {"serve"}, 50.0)
    assert failures == []
    assert any("derived 0.9 -> 0.3" in ln for ln in lines)


def test_injected_paged_regression_fails_gate():
    """Acceptance: a 1.5x slowdown on serve/paged/us_per_token trips the
    25% gate; 1.1x does not."""
    fresh = _rec("serve", [
        ("serve/paged/us_per_token", 1500.0, 66.0),
        ("serve/continuous/us_per_token", 990.0, 100.0),
    ])
    _, failures = diff_records(fresh, BASE, 0.25, {"serve"}, 50.0)
    assert len(failures) == 1
    assert "serve/paged/us_per_token" in failures[0]

    ok = _rec("serve", [("serve/paged/us_per_token", 1100.0, 91.0)])
    _, failures = diff_records(ok, BASE, 0.25, {"serve"}, 50.0)
    assert failures == []


def test_calibration_blip_cannot_fail_alone():
    """raw 1.5x but the fresh calibration says the machine is 2x slower
    -> normalized 0.75x: a slow runner, not a regression. And a fast
    machine (calib 0.5x) with raw exactly 1.0x -> normalized 2x: a
    calibration misread, raw ratio vetoes the failure."""
    slow = _rec("serve", [("serve/paged/us_per_token", 1500.0, 66.0)],
                calib=200.0)
    _, failures = diff_records(slow, BASE, 0.25, {"serve"}, 50.0)
    assert failures == []
    fast = _rec("serve", [("serve/paged/us_per_token", 1000.0, 100.0)],
                calib=50.0)
    _, failures = diff_records(fast, BASE, 0.25, {"serve"}, 50.0)
    assert failures == []


def test_noise_floor_rows_never_gate():
    base = _rec("serve", [("serve/paged/us_per_token", 10.0, 1.0)])
    fresh = _rec("serve", [("serve/paged/us_per_token", 40.0, 0.2)])
    _, failures = diff_records(fresh, base, 0.25, {"serve"}, 50.0)
    assert failures == []       # 40us < --min-us 50us floor


@pytest.mark.parametrize("gate_tables,expect", [({"serve"}, 1), (set(), 0),
                                                ({"kernel"}, 0)])
def test_gate_scope_respects_table_selection(gate_tables, expect):
    fresh = _rec("serve", [("serve/paged/us_per_token", 2000.0, 50.0)])
    _, failures = diff_records(fresh, BASE, 0.25, gate_tables, 50.0)
    assert len(failures) == expect


KBASE = _rec("kernel", [
    ("kernel/paged_attn/decode", 800.0, "T=128"),
    ("kernel/paged_attn/gather_oracle", 600.0, "gathered_mb=4.0"),
    ("kernel/b32/r75/mvm", 1000.0, "pad_flop_ratio=1.2"),
])


def test_injected_paged_attn_regression_fails_gate():
    """Acceptance: a 1.5x slowdown on kernel/paged_attn/decode trips the
    default gate-row pattern (the | alternative next to /mvm); the
    informational gather-oracle row never gates, however large."""
    fresh = _rec("kernel", [
        ("kernel/paged_attn/decode", 1200.0, "T=128"),        # 1.5x
        ("kernel/paged_attn/gather_oracle", 60000.0, "huge"),  # 100x: ok
        ("kernel/b32/r75/mvm", 1050.0, "pad_flop_ratio=1.2"),
    ])
    _, failures = diff_records(fresh, KBASE, 0.25, {"kernel"}, 50.0)
    assert len(failures) == 1
    assert "kernel/paged_attn/decode" in failures[0]


def test_gate_row_alternatives_cover_mvm_and_paged_attn():
    """Both | alternatives of the kernel pattern gate independently."""
    fresh = _rec("kernel", [
        ("kernel/paged_attn/decode", 1200.0, "T=128"),        # 1.5x
        ("kernel/b32/r75/mvm", 1500.0, "pad_flop_ratio=1.2"),  # 1.5x
    ])
    _, failures = diff_records(fresh, KBASE, 0.25, {"kernel"}, 50.0)
    assert len(failures) == 2
    assert any("kernel/paged_attn/decode" in f for f in failures)
    assert any("kernel/b32/r75/mvm" in f for f in failures)


# ---------------------------------------------------------------------------
# realtime budget gate (serve/frames p99)
# ---------------------------------------------------------------------------

P99 = "serve/frames/p99_us_per_frame"


def _p99(us, calib=100.0):
    return _rec("serve", [(P99, us, f"realtime_500us={us < 500}")],
                calib=calib)


def test_p99_within_budget_never_gates_on_ratio():
    """A 4x p99 drift that stays under the budget is NOT a failure —
    tail latency gates on the absolute frame deadline, not the ratio."""
    _, failures = diff_records(_p99(400.0), _p99(100.0), 0.25,
                               {"serve"}, 50.0)
    assert failures == []


def test_p99_crossing_budget_fails():
    _, failures = diff_records(_p99(600.0), _p99(450.0), 0.25,
                               {"serve"}, 50.0)
    assert len(failures) == 1
    assert "crossed the realtime budget" in failures[0]
    # normalization applies: same 600us on a 2x-slower machine is
    # 300us normalized — under budget, no failure
    _, failures = diff_records(_p99(600.0, calib=200.0), _p99(450.0),
                               0.25, {"serve"}, 50.0)
    assert failures == []


def test_p99_both_over_budget_falls_back_to_ratio_rule():
    """Budget unreachable on this config: only a genuine >threshold
    regression fails (same both-ratios rule as relative rows)."""
    _, failures = diff_records(_p99(900.0), _p99(800.0), 0.25,
                               {"serve"}, 50.0)
    assert failures == []                       # 1.13x, within threshold
    _, failures = diff_records(_p99(1300.0), _p99(800.0), 0.25,
                               {"serve"}, 50.0)
    assert len(failures) == 1 and "over the 500us budget" in failures[0]


def test_p99_budget_configurable_and_disableable():
    _, failures = diff_records(_p99(600.0), _p99(450.0), 0.25,
                               {"serve"}, 50.0,
                               realtime_budget_us=1000.0)
    assert failures == []
    _, failures = diff_records(_p99(600.0), _p99(450.0), 0.25,
                               {"serve"}, 50.0, realtime_row="")
    assert failures == []


def test_injected_prefix_regression_fails_gate():
    """Acceptance: the new serve/prefix/us_per_token row auto-matches
    the serve:/us_per pattern — an injected 1.5x regression trips it."""
    base = _rec("serve", [("serve/prefix/us_per_token", 1000.0, 100.0)])
    fresh = _rec("serve", [("serve/prefix/us_per_token", 1500.0, 66.0)])
    _, failures = diff_records(fresh, base, 0.25, {"serve"}, 50.0)
    assert len(failures) == 1
    assert "serve/prefix/us_per_token" in failures[0]


def test_injected_disagg_regression_fails_gate():
    """Acceptance (ISSUE 9): serve/disagg/us_per_token is gated by the
    same serve:/us_per pattern — an injected 1.5x regression trips it,
    while the informational router SLO row (us_per_call=0) never gates
    no matter how badly attainment collapses."""
    base = _rec("serve", [
        ("serve/disagg/us_per_token", 1000.0, 100.0),
        ("serve/router/slo_attainment", 0.0,
         "round_robin=1.0000(p99=500.0us)"),
    ])
    fresh = _rec("serve", [
        ("serve/disagg/us_per_token", 1500.0, 66.0),        # 1.5x
        ("serve/router/slo_attainment", 0.0,
         "round_robin=0.1000(p99=9000.0us)"),               # collapse: ok
    ])
    _, failures = diff_records(fresh, base, 0.25, {"serve"}, 50.0)
    assert len(failures) == 1
    assert "serve/disagg/us_per_token" in failures[0]

    ok = _rec("serve", [("serve/disagg/us_per_token", 1100.0, 91.0)])
    _, failures = diff_records(ok, base, 0.25, {"serve"}, 50.0)
    assert failures == []                                   # 1.1x passes


def test_injected_speculative_regression_fails_gate():
    """Acceptance (ISSUE 10): serve/speculative/us_per_token is gated
    by the same serve:/us_per pattern — an injected 1.5x regression
    trips it, while the informational acceptance-rate and
    speedup-vs-prune rows (us_per_call=0, payload in derived) never
    gate no matter how far acceptance collapses."""
    base = _rec("serve", [
        ("serve/speculative/us_per_token", 1000.0, 100.0),
        ("serve/speculative/acceptance", 0.0,
         "k=4;prune=0.5;rate=0.9000;rounds=40;tokens_per_round=4.100"),
        ("serve/speculative/speedup_vs_prune", 0.0,
         "prune0.0:accept=1.000,speedup=1.400x"),
    ])
    fresh = _rec("serve", [
        ("serve/speculative/us_per_token", 1500.0, 66.0),   # 1.5x
        ("serve/speculative/acceptance", 0.0,
         "k=4;prune=0.5;rate=0.0100;rounds=400;"            # collapse: ok
         "tokens_per_round=1.010"),
        ("serve/speculative/speedup_vs_prune", 0.0,
         "prune0.0:accept=0.010,speedup=0.200x"),
    ])
    _, failures = diff_records(fresh, base, 0.25, {"serve"}, 50.0)
    assert len(failures) == 1
    assert "serve/speculative/us_per_token" in failures[0]

    ok = _rec("serve", [("serve/speculative/us_per_token", 1100.0, 91.0)])
    _, failures = diff_records(ok, base, 0.25, {"serve"}, 50.0)
    assert failures == []                                   # 1.1x passes
