"""Sharding rules + roofline machinery (no multi-device needed here;
full-mesh lowering is exercised by launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.dist.api import Rules, shard, use_rules
from repro.dist.rules import ShardingPolicy, param_specs
from repro.launch.hlo_cost import HloCostModel, analyze
from repro.launch.roofline import model_flops, parse_collectives, roofline
from repro.models import abstract_params
from repro.models.config import SHAPES


class _FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_cover_tree(arch):
    cfg = get_config(arch)
    ap = abstract_params(cfg)
    specs = param_specs(cfg, ap, _FakeMesh(), ShardingPolicy())
    n_p, n_s = len(jax.tree.leaves(ap)), len(
        jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))
    assert n_p == n_s
    # every sharded dim must divide the axis size
    for leaf, spec in zip(
            jax.tree.leaves(ap),
            jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax == "model":
                assert dim % 16 == 0, (arch, leaf.shape, spec)


def test_shard_noop_without_rules():
    x = jnp.ones((4, 4))
    assert shard(x, "residual") is x


def test_rules_update():
    r = Rules({"a": P("data")})
    r2 = r.updated(b=P("model"))
    assert r2.get("a") == P("data") and r2.get("b") == P("model")


def _tiny_mesh():
    """A real (trivial) mesh on the single host device."""
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))


def test_nested_use_rules_restores_outer():
    from repro.dist.api import current_rules
    outer = Rules({"residual": P("data")})
    inner = Rules({"residual": P("model")})
    assert current_rules() is None
    with use_rules(outer):
        assert current_rules() is outer
        with use_rules(inner):
            assert current_rules() is inner
        assert current_rules() is outer
    assert current_rules() is None


def test_nested_use_rules_restores_on_error():
    from repro.dist.api import current_rules
    outer = Rules({"residual": P("data")})
    with use_rules(outer):
        with pytest.raises(RuntimeError):
            with use_rules(Rules({})):
                raise RuntimeError("boom")
        assert current_rules() is outer
    assert current_rules() is None


def test_unknown_logical_name_passes_through():
    x = jnp.ones((4, 4))
    with use_rules(Rules({"residual": P("data")}, mesh=_tiny_mesh())):
        assert shard(x, "no_such_name") is x


def test_shard_noop_on_trivial_mesh():
    """A 1x1 mesh must leave single-device paths untouched even when a
    rule matches — shard returns the identical object."""
    x = jnp.ones((4, 4))
    with use_rules(Rules({"residual": P("data", "model")},
                         mesh=_tiny_mesh())):
        assert shard(x, "residual") is x


def test_fit_spec_divisibility_guard():
    from repro.dist.api import fit_spec
    mesh = _FakeMesh()
    # 40 % 16 != 0 -> model axis dropped; 32 % 16 == 0 -> data kept
    assert fit_spec(P("data", "model"), (32, 40), mesh) == P("data", None)
    # nothing divides -> no constraint at all
    assert fit_spec(P("model"), (7, 7), mesh) is None
    # unknown mesh axis names are dropped, not an error
    assert fit_spec(P("expert", "model"), (16, 16), mesh) == P(None, "model")


HLO = """
HloModule test

%body.1 (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %g = f32[128,256]{1,0} get-tuple-element(%p), index=1
  %d = f32[128,128]{1,0} dot(%g, %g), lhs_contracting_dims={1}, rhs_contracting_dims={1}
  %ar = f32[128,256]{1,0} all-reduce(%g), replica_groups={}
  ROOT %t = (s32[], f32[128,256]) tuple(%p, %ar)
}

%cond.1 (p: (s32[], f32[128,256])) -> pred[] {
  %p = (s32[], f32[128,256]) parameter(0)
  ROOT %c = pred[] constant(true)
}

ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %a = f32[128,256]{1,0} parameter(0)
  %w = (s32[], f32[128,256]) while(%a), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %r = f32[128,256]{1,0} get-tuple-element(%w), index=1
}
"""


def test_hlo_cost_loop_multiplication():
    c = analyze(HLO)
    # dot: 2*128*128*256 flops, x10 trips
    assert c.flops == pytest.approx(2 * 128 * 128 * 256 * 10, rel=0.01)
    # all-reduce operand: 128*256*4 bytes x10
    assert c.coll_bytes["all-reduce"] == pytest.approx(
        128 * 256 * 4 * 10, rel=0.01)
    assert c.coll_count["all-reduce"] == 10


def test_parse_collectives_operand_sizes():
    stats = parse_collectives(HLO)
    assert stats.bytes_by_op["all-reduce"] == 128 * 256 * 4
    assert stats.count_by_op["all-reduce"] == 1


def test_roofline_dominance():
    r = roofline(1e15, 1e9, 1e6, 0.9e15)
    assert r.dominant == "compute"
    assert 0.89 <= r.useful_ratio <= 0.91
    r = roofline(1e9, 1e13, 1e6, 1e9)
    assert r.dominant == "memory"
    r = roofline(1e9, 1e9, 1e13, 1e9)
    assert r.dominant == "collective"


def test_model_flops_kinds():
    cfg = get_config("gemma-2b")
    tr = model_flops(cfg, SHAPES["train_4k"], 256)
    pf = model_flops(cfg, SHAPES["prefill_32k"], 256)
    de = model_flops(cfg, SHAPES["decode_32k"], 256)
    assert tr == pytest.approx(6 * cfg.param_count() * 4096 * 256 / 256)
    assert pf == pytest.approx(2 * cfg.param_count() * 32768 * 32 / 256)
    assert de == pytest.approx(2 * cfg.param_count() * 128 / 256)


def test_runnability_matrix():
    from repro.configs import all_cells, cell_is_runnable
    cells = all_cells()
    assert len(cells) == 40
    skipped = [(a, s) for a, s in cells if not cell_is_runnable(a, s)]
    assert len(skipped) == 8
    assert all(s == "long_500k" for _, s in skipped)
    runnable_long = [a for a, s in cells
                     if s == "long_500k" and cell_is_runnable(a, s)]
    assert sorted(runnable_long) == ["hymba-1.5b", "mamba2-370m"]
