"""Per-assigned-architecture smoke tests (deliverable f): reduced config
of the same family, one forward/train step on CPU, shapes + no NaNs.
The FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models import forward_loss, init_params, prefill, decode_step


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_reduced_train_step(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(hash(arch) % 2**31)
    params = init_params(key, cfg)
    b, s = 2, 32
    if cfg.n_codebooks:
        toks = jax.random.randint(key, (b, s, cfg.n_codebooks), 0, cfg.vocab)
    else:
        toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.n_img_tokens:
        batch["img_embeds"] = jax.random.normal(
            key, (b, cfg.n_img_tokens, 1024))

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p, bt: forward_loss(p, bt, cfg)))(params, batch)
    assert np.isfinite(float(loss)), (arch, loss)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0.0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_reduced_prefill_decode(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(7)
    params = init_params(key, cfg)
    b, s = 2, 16
    if cfg.n_codebooks:
        toks = jax.random.randint(key, (b, s, cfg.n_codebooks), 0, cfg.vocab)
        nxt = toks[:, :1]
    else:
        toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
        nxt = toks[:, :1]
    batch = {"tokens": toks}
    if cfg.n_img_tokens:
        batch["img_embeds"] = jax.random.normal(
            key, (b, cfg.n_img_tokens, 1024))
    logits, cache = jax.jit(lambda p, bt: prefill(p, bt, cfg))(params, batch)
    assert np.isfinite(np.asarray(logits)).all(), arch
    total = s + (cfg.n_img_tokens or 0)
    from repro.serve.engine import grow_cache
    cache = grow_cache(cache, 1)
    lg, _ = jax.jit(lambda p, c, t: decode_step(p, c, t,
                                                jnp.asarray(total), cfg))(
        params, cache, nxt)
    assert np.isfinite(np.asarray(lg)).all(), arch


def test_full_config_param_counts():
    """Sanity: full configs land near their nameplate sizes."""
    expect = {
        "mamba2-370m": (0.30e9, 0.55e9),
        "deepseek-v2-236b": (200e9, 260e9),
        "llama4-scout-17b-a16e": (80e9, 120e9),   # 16 experts materialized
        "musicgen-medium": (1.2e9, 2.2e9),
        "internlm2-20b": (17e9, 23e9),
        "qwen3-32b": (30e9, 36e9),
        "llama3-405b": (380e9, 430e9),
        "gemma-2b": (2.0e9, 3.2e9),
        "internvl2-2b": (1.5e9, 2.6e9),
        "hymba-1.5b": (1.0e9, 2.0e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params_below_total():
    cfg = get_config("deepseek-v2-236b")
    assert cfg.active_param_count() < 0.15 * cfg.param_count()
    cfg = get_config("llama4-scout-17b-a16e")
    assert cfg.active_param_count() < 0.35 * cfg.param_count()
