"""The observability layer (``repro.obs``): tracer semantics, metric
math, Chrome-trace export shape, and the wiring through the serve /
train / dist stacks.

The pins that matter:

* the DISABLED path is allocation-free (tracing must not move the
  gated ``serve/*/us_per*`` perf numbers when off),
* the ring buffer wraps without growing and counts what it dropped,
* histogram percentiles are exact nearest-rank at tiny sample counts,
* every exported event carries the Chrome ``trace_event`` required
  fields, so the file loads in Perfetto unmodified.
"""
import importlib.util
import json
import os
import tracemalloc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.obs import metrics, trace
from repro.obs.metrics import Histogram, MetricsRegistry
from repro.obs.summary import (
    format_table, load_trace, request_table, summarize,
)
from repro.obs.trace import Tracer

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

@pytest.fixture(autouse=True)
def _obs_off():
    """Global tracer/registry must never leak between tests."""
    obs.disable_all()
    yield
    obs.disable_all()


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------

def test_span_nesting_contained():
    tr = Tracer(capacity=16)
    tr.begin("outer", track="t")
    tr.begin("inner", track="t")
    tr.end()
    tr.end(args={"k": 1})
    evs = tr.events()
    assert [e[1] for e in evs] == ["inner", "outer"]  # inner closes first
    (_, _, i_ts, i_dur, _, _), (_, _, o_ts, o_dur, _, o_args) = evs
    assert o_ts <= i_ts and i_ts + i_dur <= o_ts + o_dur
    assert o_args == {"k": 1}


def test_span_context_manager_records_x_event():
    tr = Tracer(capacity=8)
    with tr.span("work", track="main", args={"n": 3}):
        pass
    (ph, name, ts, dur, tid, args), = tr.events()
    assert (ph, name, tid, args) == ("X", "work", "main", {"n": 3})
    assert dur >= 0


def test_ring_wraparound_keeps_newest():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.instant(f"e{i}")
    assert tr.dropped == 6
    evs = tr.events()
    assert len(evs) == 4
    assert [e[1] for e in evs] == ["e6", "e7", "e8", "e9"]  # oldest first
    ts = [e[2] for e in evs]
    assert ts == sorted(ts)


def test_disabled_span_is_shared_singleton():
    assert trace.get() is None
    s = trace.span("a")
    assert s is trace.span("b")
    with s:
        pass                      # usable as a context manager
    trace.instant("nothing")      # no-op, no error
    assert trace.export_chrome("/tmp/should_not_exist.json") is None


def test_disabled_hot_path_is_allocation_free():
    """With tracing off, the instrumentation gate must not allocate:
    no dict, no tuple, no span object — one global read and a branch.
    tracemalloc attributes allocations to trace.py if any happen."""
    assert trace.get() is None
    trace_file = trace.__file__

    n = 10_000

    def hot_loop():
        for _ in range(n):
            trace.span("serve/decode_step")
            trace.instant("serve/sched/admit")
            trace.get()

    hot_loop()                                      # warm any caches
    tracemalloc.start()
    try:
        before = tracemalloc.take_snapshot()
        hot_loop()
        after = tracemalloc.take_snapshot()
    finally:
        tracemalloc.stop()
    flt = (tracemalloc.Filter(True, trace_file),)
    grew = sum(st.size_diff for st in after.filter_traces(flt)
               .compare_to(before.filter_traces(flt), "lineno"))
    # snapshots see LIVE blocks: anything retained per call would grow
    # linearly (>= n bytes over 10k calls). The few hundred bytes of
    # slack is the last iteration's frame objects, which tracemalloc
    # itself keeps alive at snapshot time.
    assert grew < n // 10, f"disabled tracer retained {grew} bytes/{n} calls"


def test_enable_disable_roundtrip():
    tr = trace.enable(capacity=8)
    assert trace.get() is tr and trace.enabled()
    with trace.span("x"):
        pass
    got = trace.disable()
    assert got is tr and trace.get() is None
    assert len(got.events()) == 1       # export still works post-disable


# ---------------------------------------------------------------------------
# chrome export
# ---------------------------------------------------------------------------

def test_chrome_export_required_fields(tmp_path):
    tr = trace.enable(capacity=32)
    with trace.span("outer", track="engine", args={"rid": 1}):
        trace.instant("mark", track="engine")
    tr.complete("timed", tr.now_ns() - 1000, 1000, track="req 0")
    path = trace.export_chrome(str(tmp_path / "t.json"))
    obj = json.load(open(path))
    evs = obj["traceEvents"]
    assert evs, "no events exported"
    for ev in evs:
        for field in ("ph", "ts", "pid", "tid", "name"):
            assert field in ev, (field, ev)
    xs = [e for e in evs if e["ph"] == "X"]
    assert xs and all("dur" in e for e in xs)
    inst = [e for e in evs if e["ph"] == "i"]
    assert inst and all(e["s"] == "t" for e in inst)
    # one thread_name metadata row per distinct track, Perfetto labels
    meta = {e["args"]["name"] for e in evs
            if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"engine", "req 0"} <= meta
    assert obj["otherData"]["dropped_events"] == 0


def test_summary_tables_from_export(tmp_path):
    tr = trace.enable()
    for name, dur in (("a", 100), ("a", 300), ("b", 50)):
        tr.complete(name, tr.now_ns(), dur * 1000)
    path = trace.export_chrome(str(tmp_path / "t.json"))
    rows = summarize(load_trace(path))
    assert [r["name"] for r in rows] == ["a", "b"]   # by total desc
    a = rows[0]
    assert a["count"] == 2 and a["p50_us"] == 100 and a["max_us"] == 300
    assert request_table(load_trace(path)) == []     # no serve spans
    txt = format_table(rows)
    assert "a" in txt and "p99_us" in txt


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_histogram_percentiles_tiny_counts():
    h = Histogram()
    assert h.percentile(50) is None
    assert h.summary()["p99"] is None and h.summary()["count"] == 0
    h.observe(5.0)
    assert (h.percentile(50), h.percentile(99)) == (5.0, 5.0)
    h2 = Histogram()
    h2.observe(2.0)
    h2.observe(1.0)
    # nearest-rank: p50 of [1, 2] is the 1st sample, not 1.5
    assert h2.percentile(50) == 1.0
    assert h2.percentile(95) == 2.0 and h2.percentile(99) == 2.0
    s = h2.summary()
    assert s["count"] == 2 and s["mean"] == 1.5 and s["min"] == 1.0


def test_histogram_sample_cap_counts_dropped():
    h = Histogram(max_samples=3)
    for v in (1, 2, 3, 4, 5):
        h.observe(v)
    assert h.count == 5 and h.dropped == 2
    assert h.summary()["mean"] == 3.0      # sum tracks all observations


def test_registry_kinds_and_export():
    reg = MetricsRegistry()
    reg.counter("serve/sched/admitted").inc()
    reg.counter("serve/sched/admitted").inc(2)
    g = reg.gauge("serve/pool/pages")
    g.set(3)
    g.set(5)
    reg.histogram("serve/req/ttft_us").observe(10.0)
    with pytest.raises(ValueError):
        reg.gauge("serve/sched/admitted")   # name bound to counter
    d = json.loads(reg.to_json())
    assert d["counters"]["serve/sched/admitted"] == 3
    assert d["gauges"]["serve/pool/pages"]["last"] == 5
    assert d["gauges"]["serve/pool/pages"]["series"] == [3.0, 5.0]
    assert d["histograms"]["serve/req/ttft_us"]["count"] == 1
    assert "series" not in reg.to_dict(series=False)["gauges"][
        "serve/pool/pages"]


def test_metrics_module_gate():
    assert metrics.get() is None
    reg = metrics.enable()
    assert metrics.get() is reg
    assert metrics.disable() is reg and metrics.get() is None
    # registry() auto-enables (docs/interactive convenience)
    r2 = metrics.registry()
    assert metrics.get() is r2


# ---------------------------------------------------------------------------
# wiring: serve engine / paging / frames / train / csb partition
# ---------------------------------------------------------------------------

from repro.models import ModelConfig, init_params as lm_init  # noqa: E402
from repro.serve import EngineConfig, Request, \
    serve_continuous                                          # noqa: E402

TINY = ModelConfig(name="tiny-obs", mixer="attn", ffn="swiglu", n_layers=2,
                   d_model=32, n_heads=4, n_kv=2, head_dim=16, d_ff=64,
                   vocab=50, dtype="float32", logit_chunk=16, remat=False)


def _reqs(n=4, seed=0):
    r = np.random.default_rng(seed)
    return [Request(rid=i,
                    tokens=list(r.integers(1, 50, size=int(r.integers(3, 9)))),
                    max_new_tokens=3, arrival=i // 2)
            for i in range(n)]


def test_serve_continuous_request_lifecycle(tmp_path):
    tr, reg = obs.enable_all()
    params = lm_init(jax.random.PRNGKey(0), TINY)
    res = serve_continuous(params, TINY, _reqs(4),
                           EngineConfig(n_slots=2, cache_len=32))
    # satellite 1: compile vs steady-state throughput, both always on
    assert res.stats["compile_time_s"] >= 0.0
    assert "steady_tokens_per_sec" in res.stats
    assert "tokens_per_sec" in res.stats
    # one lifecycle histogram sample per request
    for name in ("serve/req/ttft_us", "serve/req/queue_wait_us",
                 "serve/req/prefill_us", "serve/req/decode_per_token_us"):
        assert reg.histogram(name).count == 4, name
    assert reg.counter("serve/sched/admitted").value == 4
    path = trace.export_chrome(str(tmp_path / "serve.json"))
    names = {e["name"] for e in load_trace(path)}
    for want in ("serve/req/queue_wait", "serve/req/prefill",
                 "serve/req/ttft", "serve/req/decode", "serve/req/finish",
                 "serve/decode_step", "serve/sched/admit"):
        assert want in names, want
    # ...and the lifecycle table renders from the file
    rows = request_table(load_trace(path))
    assert [r["name"] for r in rows] == [
        "serve/req/queue_wait", "serve/req/prefill",
        "serve/req/ttft", "serve/req/decode"]
    assert all(r["count"] == 4 for r in rows)


def test_serve_stats_keys_present_when_disabled():
    """The throughput-accounting split is real engine state, not an
    obs side effect — present with tracing off."""
    assert trace.get() is None and metrics.get() is None
    params = lm_init(jax.random.PRNGKey(0), TINY)
    res = serve_continuous(params, TINY, _reqs(2),
                           EngineConfig(n_slots=2, cache_len=32))
    assert "compile_time_s" in res.stats
    assert "steady_tokens_per_sec" in res.stats
    res0 = serve_continuous(params, TINY, [], EngineConfig(n_slots=2))
    assert res0.stats["compile_time_s"] == 0.0


def test_paged_serve_pool_gauges():
    _, reg = obs.enable_all()
    params = lm_init(jax.random.PRNGKey(0), TINY)
    res = serve_continuous(params, TINY, _reqs(4, seed=1),
                           EngineConfig(n_slots=2, cache_len=32,
                                        paged=True, page_size=8))
    g = reg.gauge("serve/pool/pages")
    assert g.last is not None and g.last >= 0
    # one pool sample per decode step: the timeline the stats can't give
    assert len(g.series) == res.stats["decode_steps"]
    assert len(reg.gauge("serve/pool/fragmentation").series) == \
        res.stats["decode_steps"]


def test_rnn_serve_frames_spans():
    from repro.cells import init_params as cell_init, make_cell
    from repro.serve import rnn_serve_frames
    tr, reg = obs.enable_all()
    cell = make_cell("lstm", 8, 16)
    params = cell_init(cell, jax.random.PRNGKey(2))
    frames = jax.random.normal(jax.random.PRNGKey(3), (5, 2, 8))
    out = rnn_serve_frames(cell, params, frames, warmup=1,
                           collect_frame_times=True)
    assert len(out) == 4
    frame_spans = [e for e in tr.events()
                   if e[0] == "X" and e[1] == "serve/frame"]
    assert len(frame_spans) == 5
    assert reg.histogram("serve/frames/wall_us").count == 5


def test_train_loop_step_spans():
    from repro.train import TrainConfig, train
    tr, reg = obs.enable_all()

    def loss_fn(params, batch):
        return jnp.mean((batch["x"] @ params["w"] - batch["y"]) ** 2)

    params = {"w": jnp.ones((4, 2), jnp.float32)}
    batches = ((s, {"x": jnp.ones((2, 4)), "y": jnp.zeros((2, 2))})
               for s in range(3))
    tcfg = TrainConfig(steps=3, log_every=100)
    _, history = train(loss_fn, params, batches, tcfg,
                       log=lambda *_: None)
    assert len(history) == 3
    assert reg.histogram("train/step/wall_us").count == 3
    assert reg.gauge("train/step/loss").last is not None
    steps = [e for e in tr.events() if e[1] == "train/step"]
    assert len(steps) == 3 and steps[0][4] == "train"


def test_csb_partition_balance_gauge(rng):
    from repro.core import padded_csb_from_dense
    from repro.dist.csb_partition import partition_padded
    tr, reg = obs.enable_all()
    z = np.zeros((128, 64), np.float32)
    z[:32] = rng.normal(size=(32, 64))
    p = padded_csb_from_dense(z, 16, 16)
    plan, _ = partition_padded(p, 4)
    g = reg.gauge("dist/csb_partition/imbalance")
    assert g.last == pytest.approx(plan.imbalance)
    assert reg.gauge("dist/csb_partition/max_device_cycles").last == \
        max(plan.device_cycles)
    inst = [e for e in tr.events() if e[1] == "dist/csb_partition"]
    assert inst and inst[-1][5]["policy"] == "greedy"


# ---------------------------------------------------------------------------
# tools/hlo_diff.py (satellite: sharded-vs-unsharded decode probe)
# ---------------------------------------------------------------------------

def _load_hlo_diff():
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "tools", "hlo_diff.py")
    spec = importlib.util.spec_from_file_location("hlo_diff_tool", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@needs8
def test_hlo_diff_smoke(tmp_path):
    """The probe lowers + structurally diffs both programs and the
    sharded one actually differs (collectives appear)."""
    hd = _load_hlo_diff()
    res = hd.hlo_diff("attn", (2, 4), stage="stablehlo",
                      out_dir=str(tmp_path))
    assert res["ops_unsharded"] > 0 and res["ops_sharded"] > 0
    assert res["n_changed_lines"] > 0          # shardings change the text
    assert len(res["files"]) == 2
    for f in res["files"]:
        assert os.path.getsize(f) > 0
