"""Copy-on-write prefix sharing: token parity + PagePool trie semantics.

The acceptance bar mirrors test_serve_paged: ``prefix_cache=True`` must
be token-for-token identical to the non-shared paged engine — for attn
and MLA mixers, against the scalar-pos ``generate`` reference, unsharded
and on 1x8 / 2x4 host meshes (mesh cases need 8 devices; CI sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8``). On top of
parity, admission must actually share: nonzero ``prefix_hits``, fewer
prefill tokens, CoW on mid-page divergence.

The PagePool half unit-tests the radix-trie allocator directly:
try_reserve accounting, token-granular partial matches, CoW remapping,
the write-isolation guard, trie retention past release, LRU reclaim
under pressure and drop_prefix_cache — with ``check()`` asserted after
every mutation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.models import ModelConfig
from repro.models import init_params as lm_init
from repro.serve import (
    EngineConfig, PagePool, Request, generate, pages_for, serve_continuous,
)

CFG_ATTN = ModelConfig(name="tiny-prefix", mixer="attn", ffn="swiglu",
                       n_layers=2, d_model=32, n_heads=2, n_kv=2,
                       head_dim=16, d_ff=64, vocab=50, dtype="float32",
                       logit_chunk=16, remat=False)
CFG_MLA = ModelConfig(name="tiny-prefix-mla", mixer="mla", ffn="swiglu",
                      n_layers=2, d_model=32, n_heads=2, n_kv=2,
                      head_dim=16, d_ff=64, vocab=50, kv_lora=16,
                      q_lora=16, rope_head_dim=8, dtype="float32",
                      logit_chunk=16, remat=False)
CFGS = {"attn": CFG_ATTN, "mla": CFG_MLA}

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def params_by_mixer():
    return {name: lm_init(jax.random.PRNGKey(0), cfg)
            for name, cfg in CFGS.items()}


def _shared_trace(seed=7, sys_len=9, n=6, vocab=50):
    """n requests sharing one system prompt, staggered arrivals, random
    short tails — sys_len=9 with page_size=4 puts divergence mid-page,
    so the trace exercises CoW, not just whole-page hits."""
    rng = np.random.default_rng(seed)
    sys_p = rng.integers(0, vocab, size=sys_len)
    reqs = []
    for i in range(n):
        tail = rng.integers(0, vocab, size=int(rng.integers(1, 5)))
        reqs.append(Request(rid=i, tokens=np.concatenate([sys_p, tail]),
                            max_new_tokens=4, arrival=(i // 3) * 2))
    return reqs


def _run(params, cfg, reqs, *, prefix, mesh=None):
    return serve_continuous(params, cfg, reqs,
                            EngineConfig(n_slots=2, paged=True, page_size=4,
                                         prefix_cache=prefix), mesh=mesh)


# ---------------------------------------------------------------------------
# token parity (acceptance)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mixer", ["attn", "mla"])
def test_prefix_on_matches_off_and_generate(params_by_mixer, mixer):
    cfg, params = CFGS[mixer], params_by_mixer[mixer]
    reqs = _shared_trace()
    off = _run(params, cfg, reqs, prefix=False)
    on = _run(params, cfg, reqs, prefix=True)
    assert on.tokens == off.tokens
    assert on.stats["prefix_cache"] and not off.stats["prefix_cache"]
    # the scalar-pos reference: generate() decodes with a scalar position
    for r in reqs:
        ref = generate(params, cfg, jnp.asarray(r.tokens)[None],
                       EngineConfig(max_new_tokens=r.max_new_tokens))
        np.testing.assert_array_equal(
            on.tokens[r.rid], np.asarray(ref)[0, len(r.tokens):],
            err_msg=f"request {r.rid}")


@pytest.mark.parametrize("mixer", ["attn", "mla"])
def test_prefix_sharing_actually_shares(params_by_mixer, mixer):
    cfg, params = CFGS[mixer], params_by_mixer[mixer]
    reqs = _shared_trace()
    off = _run(params, cfg, reqs, prefix=False)
    on = _run(params, cfg, reqs, prefix=True)
    # every request after the first should hit the shared system prompt
    assert on.stats["prefix_hits"] == len(reqs) - 1
    assert on.stats["shared_pages"] > 0
    assert on.stats["prefill_tokens"] < off.stats["prefill_tokens"]
    # 9-token prompt, page_size=4: divergence lands inside page 2 -> CoW
    assert on.stats["paging"]["cow_copies"] > 0
    assert "prefix_hits" not in off.stats


def test_prefix_off_by_default_and_requires_paged(params_by_mixer):
    params = params_by_mixer["attn"]
    reqs = _shared_trace(n=2)
    res = serve_continuous(params, CFG_ATTN, reqs,
                           EngineConfig(n_slots=2, paged=True, page_size=4))
    assert not res.stats["prefix_cache"]
    with pytest.raises(ValueError, match="prefix_cache"):
        serve_continuous(params, CFG_ATTN, reqs,
                         EngineConfig(n_slots=2, prefix_cache=True))


@needs8
@pytest.mark.parametrize("mixer", ["attn", "mla"])
@pytest.mark.parametrize("mesh_shape", [(1, 8), (2, 4)])
def test_prefix_parity_sharded(params_by_mixer, mixer, mesh_shape):
    cfg, params = CFGS[mixer], params_by_mixer[mixer]
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(mesh_shape),
                ("data", "model"))
    reqs = _shared_trace()
    off = _run(params, cfg, reqs, prefix=False, mesh=mesh)
    on = _run(params, cfg, reqs, prefix=True, mesh=mesh)
    assert on.tokens == off.tokens
    assert on.stats["prefix_hits"] > 0


# ---------------------------------------------------------------------------
# PagePool: trie admission accounting
# ---------------------------------------------------------------------------

def _pool(**kw):
    kw.setdefault("page_size", 4)
    kw.setdefault("n_pages", 16)
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_pages", 8)
    kw.setdefault("prefix_cache", True)
    return PagePool(**kw)


def _admit(pool, slot, tokens, max_new=0):
    """Full admission protocol for a prompt: try_reserve -> cow ->
    ensure -> register, check()ing at each step."""
    info = pool.try_reserve(slot, len(tokens) + max_new, tokens=tokens)
    assert info is not None
    pool.check()
    cow = pool.cow_if_needed(slot)
    assert (cow is not None) == info.needs_cow
    pool.check()
    pool.ensure(slot, len(tokens))
    pool.register_prefix(slot, tokens)
    pool.check()
    return info


def test_try_reserve_whole_page_hit_and_reservation():
    pool = _pool()
    a = list(range(12))                      # 3 full pages
    _admit(pool, 0, a, max_new=4)
    info = pool.try_reserve(1, 16, tokens=a)   # identical prompt + 4 new
    # matched all 12, suffix capped at plen-1 so the last token re-runs
    assert info.shared_tokens == 12 and info.shared_pages == 3
    assert info.suffix_start == 11 and info.needs_cow
    # 4 total pages - 3 shared + 1 CoW copy
    assert pool._reserved[1] == pages_for(16, 4) - 3 + 1
    pool.check()
    src_dst = pool.cow_if_needed(1)
    assert src_dst is not None
    src, dst = src_dst
    assert pool.slot_pages(1)[2] == dst != src
    assert pool.slot_pages(0)[2] == src      # slot 0 keeps the original
    pool.check()


def test_try_reserve_partial_page_match():
    pool = _pool()
    a = list(range(10))                      # pages: [0..3], [4..7] (+2 loose)
    _admit(pool, 0, a)
    b = a[:6] + [90, 91, 92]                 # diverges inside page 2
    info = pool.try_reserve(1, len(b), tokens=b)
    assert info.shared_tokens == 6 and info.shared_pages == 2
    assert info.suffix_start == 6 and info.needs_cow
    pool.check()
    assert pool.cow_if_needed(1) is not None
    pool.ensure(1, len(b))
    pool.check()


def test_try_reserve_page_aligned_divergence_no_cow():
    pool = _pool()
    a = list(range(8))
    _admit(pool, 0, a)
    b = a[:8] + [90, 91]                     # diverges exactly on boundary
    info = pool.try_reserve(1, len(b), tokens=b)
    assert info.shared_pages == 2 and info.suffix_start == 8
    assert not info.needs_cow
    assert pool.cow_if_needed(1) is None
    pool.ensure(1, len(b))
    pool.check()


def test_no_match_trivial_prefix_not_shared():
    """A 1-token common prefix is never worth sharing (suffix_start would
    be 0): try_reserve must fall back to a plain reservation."""
    pool = _pool()
    _admit(pool, 0, list(range(8)))
    info = pool.try_reserve(1, 8, tokens=[99] * 8)
    assert info.shared_pages == 0 and info.suffix_start == 0
    assert pool._reserved[1] == 2
    pool.check()


def test_write_isolation_guard_raises_without_cow():
    pool = _pool()
    a = list(range(12))
    _admit(pool, 0, a)
    info = pool.try_reserve(1, 12, tokens=a)
    assert info.needs_cow
    with pytest.raises(RuntimeError, match="cow_if_needed"):
        pool.ensure(1, 12)                   # wrote into a shared page
    pool.cow_if_needed(1)
    pool.ensure(1, 12)                       # fine after the copy
    pool.check()


def test_trie_retention_and_rehit_across_release():
    pool = _pool()
    a = list(range(8))
    _admit(pool, 0, a, max_new=4)
    pool.ensure(0, 12)                       # decode grew past the prompt
    freed = pool.release(0)
    pool.check()
    # prompt pages survive in the trie; the decode-only page was freed
    assert pool.trie_pages() == 2
    assert len(freed) == 1 and pool.allocated_total() == 2
    info = pool.try_reserve(1, 10, tokens=a + [90, 91])
    assert info.shared_pages == 2            # hit after the owner is gone
    pool.check()


def test_lru_reclaim_under_pressure():
    pool = _pool(n_pages=3, n_slots=2, max_pages=4)
    _admit(pool, 0, list(range(8)))          # 2 trie pages
    pool.release(0)
    _admit(pool, 0, [50 + i for i in range(4)])  # 1 more, LRU = first two
    pool.release(0)
    assert pool.trie_pages() == 3 and not pool._free
    # a 2-page unrelated request must evict LRU leaves, not fail
    info = pool.try_reserve(1, 8, tokens=[90 + i for i in range(8)])
    assert info is not None and info.shared_pages == 0
    pool.ensure(1, 8)
    assert pool.trie_evictions >= 2
    pool.check()


def test_try_reserve_atomic_on_capacity_failure():
    pool = _pool(n_pages=4, n_slots=2, max_pages=8)
    _admit(pool, 0, list(range(8)))          # slot 0 holds 2 of 4 pages
    ref_before = list(pool._ref)
    # shares 2 pages but needs 3 private (8 total) — only 2 exist
    assert pool.try_reserve(1, 32, tokens=list(range(8))) is None
    assert pool._ref == ref_before           # pins rolled back
    assert pool._reserved[1] == 0 and pool._n_alloc[1] == 0
    pool.check()


def test_drop_prefix_cache_frees_unmapped_only():
    pool = _pool()
    a = list(range(8))
    _admit(pool, 0, a)
    _admit(pool, 1, a + [90, 91])            # shares slot 0's two pages
    pool.release(0)
    freed = pool.drop_prefix_cache()
    # slot 1 still maps both shared pages -> nothing freeable yet
    assert freed == 0 and pool.trie_pages() == 2
    pool.release(1)
    assert pool.drop_prefix_cache() == 2
    assert pool.allocated_total() == 0
    assert sorted(pool._free) == list(range(pool.n_pages))
    pool.check()


def test_available_reduces_for_trieless_pool():
    pool = PagePool(page_size=4, n_pages=8, n_slots=2, max_pages=4)
    pool.reserve(0, 12)
    assert pool.available() == pool.n_pages - pool.reserved_total()
    pool.ensure(0, 12)
    assert pool.available() == pool.n_pages - pool.reserved_total()
    pool.check()
