"""CSB-Engine compiler + cycle simulator (paper §4.3/§5, Fig. 7/12)."""
import numpy as np
import pytest

from repro.cells import make_cell
from repro.core import CSBMatrix, CSBSpec, csb_masks, csb_project
from repro.engine.isa import compile_macro
from repro.engine.schedule import (
    greedy_schedule, no_sharing_schedule, smt_schedule,
)
from repro.engine.simulator import EngineConfig, simulate_matrix


def _csb(rng, shape=(128, 128), bm=16, bn=16, rate=0.75):
    import jax.numpy as jnp
    w = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    spec = CSBSpec(bm=bm, bn=bn, prune_rate=rate)
    z = np.asarray(csb_project(w, spec))
    rm, cm = [np.asarray(x) for x in csb_masks(w, spec)]
    return CSBMatrix.from_dense(z, bm, bn, rm, cm)


def test_macro_compile_all_cells():
    for kind in ("lstm", "gru", "lstmp", "ligru"):
        cell = make_cell(kind, 64, 128, proj_dim=64)
        prog = compile_macro(cell)
        n_mvm = len(cell.mvm_ops)
        # one-frame latency = MVM slots + the dependent tail; in steady
        # state the tail pipelines with the next frame, so THROUGHPUT is
        # bounded by the busiest unit — which must be the CSB-Engine
        # (paper §5.1.2).
        assert n_mvm <= prog.length <= n_mvm + 8, (kind, prog.length)
        # CSB-Engine must be the binding resource: every other unit POOL
        # needs no more slots (count / pool size) than the single MVM unit
        from repro.engine.isa import UNIT_POOLS
        counts = {}
        for w in prog.words:
            for u in w:
                counts[u] = counts.get(u, 0) + 1
        assert counts["CSB-Engine"] == n_mvm
        pools = {tuple(v) for v in UNIT_POOLS.values() if len(v) > 1}
        for pool in pools:
            need = sum(counts.get(u, 0) for u in pool) / len(pool)
            assert need <= n_mvm + 1, (kind, pool, need, counts)


def test_macro_respects_dependencies():
    cell = make_cell("lstm", 8, 8)
    prog = compile_macro(cell)
    slot_of = {}
    for t, w in enumerate(prog.words):
        for unit, s in w.items():
            slot_of[s.op] = t
    for op in cell.ops:
        if op.kind == "input":
            continue
        for dep in op.inputs:
            if dep in slot_of:
                assert slot_of[dep] < slot_of[op.name], (op.name, dep)


def test_sharing_improves_utilization(rng):
    csb = _csb(rng, shape=(256, 256), bm=16, bn=16, rate=0.8)
    e = EngineConfig(K=4, L=4, P=4, Q=4)
    eff_none = simulate_matrix(csb, e, "none").efficiency
    eff_1d = simulate_matrix(csb, e, "horizontal").efficiency
    eff_2d = simulate_matrix(csb, e, "2d").efficiency
    assert eff_none < eff_1d <= eff_2d + 1e-9
    assert eff_2d > 0.60
    assert eff_2d > eff_none + 0.1   # sharing is a real, material win


def test_no_sharing_efficiency_matches_formula(rng):
    csb = _csb(rng, shape=(64, 64), bm=16, bn=16, rate=0.5)
    e = EngineConfig(K=2, L=2, P=4, Q=4)
    r = simulate_matrix(csb, e, "none")
    w = csb.block_workloads()
    # manual: iterate 2x2 tiles, time = max ceil(w/16)
    total = 0
    for i0 in range(0, w.shape[0], 2):
        for j0 in range(0, w.shape[1], 2):
            tile = w[i0:i0 + 2, j0:j0 + 2]
            total += int(np.ceil(tile / 16).max())
    assert r.cycles == total
    assert abs(r.efficiency - w.sum() / (total * e.pes)) < 1e-9


def test_greedy_conserves_cycles(rng):
    """Donations move cycles between groups but never create/destroy."""
    csb = _csb(rng)
    K = L = 4
    s0 = greedy_schedule(csb.m, csb.n, K, L, 4, 4, mode="2d")
    sn = greedy_schedule(csb.m, csb.n, K, L, 4, 4, mode="2d", rounds=0)
    for a, b in zip(s0.iter_cycles, sn.iter_cycles):
        assert int(a.sum()) == int(b.sum())
        assert int(a.max()) <= int(b.max())


def test_greedy_conserves_vs_no_sharing(rng):
    """Cycle conservation: donated-plus-local cycles per iteration equal
    the no-sharing total (donations move work, never create/destroy it),
    and both max and max/mean imbalance are no worse than no-sharing."""
    csb = _csb(rng, shape=(256, 192), bm=16, bn=16, rate=0.8)
    K = L = 4
    base = no_sharing_schedule(csb.m, csb.n, K, L, 4, 4)
    for mode in ("horizontal", "vertical", "2d"):
        gre = greedy_schedule(csb.m, csb.n, K, L, 4, 4, mode=mode)
        assert len(gre.iter_cycles) == len(base.iter_cycles)
        for g, b in zip(gre.iter_cycles, base.iter_cycles):
            assert int(g.sum()) == int(b.sum()), mode
            assert int(g.max()) <= int(b.max()), mode
            assert g.max() / g.mean() <= b.max() / b.mean() + 1e-9, mode
        assert gre.total_cycles <= base.total_cycles


def test_smt_schedule_fig7_example():
    """A tiny imbalanced 2x2 iteration — SMT must balance within margin."""
    pytest.importorskip("z3")
    m = np.array([[4, 8], [2, 16]])
    n = np.array([[4, 8], [2, 16]])
    s = smt_schedule(m, n, 2, 2, 4, 4, mode="2d")
    cyc = s.iter_cycles[0]
    # unbalanced max would be ceil(16*16/16) = 16 cycles
    assert cyc.max() < 16
    assert s.solver_rounds >= 1


def test_smt_vs_greedy_balance(rng):
    pytest.importorskip("z3")
    csb = _csb(rng, shape=(64, 64), bm=16, bn=16, rate=0.7)
    K = L = 2
    gre = greedy_schedule(csb.m, csb.n, K, L, 4, 4, mode="2d")
    smt = smt_schedule(csb.m, csb.n, K, L, 4, 4, mode="2d")
    # greedy within 30% of the SMT schedule's makespan
    assert gre.total_cycles <= smt.total_cycles * 1.3 + 2
