"""RNN cell dataflow graphs + CSB-weight execution equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cells import (
    cell_apply, init_params, init_state, make_cell, rnn_scan,
)
from repro.core import (
    CSBSpec, csb_masks, csb_project, padded_csb_from_dense,
)


@pytest.mark.parametrize("kind", ["lstm", "gru", "lstmp", "ligru"])
def test_cell_shapes_finite(kind, rng):
    cell = make_cell(kind, 12, 24, proj_dim=16)
    params = init_params(cell, jax.random.PRNGKey(0))
    xs = jnp.asarray(rng.normal(size=(5, 2, 12)).astype(np.float32))
    ys, st = jax.jit(lambda p, x: rnn_scan(cell, p, x))(params, xs)
    assert np.isfinite(np.asarray(ys)).all()
    out_dim = 16 if kind == "lstmp" else 24
    assert ys.shape == (5, 2, out_dim)


def test_cell_state_dependency(rng):
    """Output at t must depend on input at t-1 (the context link)."""
    cell = make_cell("gru", 8, 16)
    params = init_params(cell, jax.random.PRNGKey(1))
    xs = jnp.asarray(rng.normal(size=(4, 1, 8)).astype(np.float32))
    ys1, _ = rnn_scan(cell, params, xs)
    xs2 = xs.at[0].add(1.0)
    ys2, _ = rnn_scan(cell, params, xs2)
    assert not np.allclose(np.asarray(ys1[-1]), np.asarray(ys2[-1]))


def test_csb_weights_match_masked_dense(rng):
    """cell_apply with PaddedCSB MVM weights == masked dense weights."""
    cell = make_cell("gru", 16, 32)
    params = init_params(cell, jax.random.PRNGKey(2))
    spec = CSBSpec(bm=8, bn=8, prune_rate=0.5)
    dense_params = {}
    csb_params = {}
    for name, w in params.items():
        if w.ndim == 2:
            z = csb_project(w, spec)
            rm, cm = csb_masks(w, spec)
            dense_params[name] = z
            csb_params[name] = padded_csb_from_dense(
                np.asarray(z), 8, 8,
                row_mask=np.asarray(rm), col_mask=np.asarray(cm))
        else:
            dense_params[name] = w
            csb_params[name] = w
    x = jnp.asarray(rng.normal(size=(2, 16)).astype(np.float32))
    st = init_state(cell, (2,))
    y_dense, _ = cell_apply(cell, dense_params, x, st)
    y_csb, _ = cell_apply(cell, csb_params, x, st)
    np.testing.assert_allclose(np.asarray(y_csb), np.asarray(y_dense),
                               rtol=2e-5, atol=2e-5)


def test_param_counts_match_table1():
    """Table 1 weight counts (weights only, bias excluded there)."""
    # MT1 layer1: LSTM 128->256: 4*(128*256 + 256*256 + 256) = 394,240
    cell = make_cell("lstm", 128, 256)
    assert cell.param_count() == 4 * (128 * 256 + 256 * 256 + 256)
    # SR4: GRU 39->256: 3*(39*256 + 256*256 + 256) = 227,328 (~226.6K+0.8K)
    cell = make_cell("gru", 39, 256)
    assert cell.param_count() == 3 * (39 * 256 + 256 * 256 + 256)
