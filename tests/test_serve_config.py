"""EngineConfig: validation, the legacy-kwarg shim, the ServeConfig shim.

The unified config is the API surface every serve entry point consumes,
so this file holds the contract: every cross-field rule fails at
construction; every old loose kwarg still works for one release but
warns and lands on the SAME engine behavior (token-for-token); unknown
kwargs raise TypeError like any real signature would.
"""
import dataclasses
import warnings

import jax
import numpy as np
import pytest

from repro.models import ModelConfig
from repro.models import init_params as lm_init
from repro.serve import (
    EngineConfig, Request, ServeConfig, generate, serve_continuous,
)
from repro.serve.config import resolve_config

CFG = ModelConfig(name="tiny-cfg", mixer="attn", ffn="swiglu", n_layers=2,
                  d_model=32, n_heads=2, n_kv=2, head_dim=16, d_ff=64,
                  vocab=50, dtype="float32", logit_chunk=16, remat=False)


@pytest.fixture(scope="module")
def params():
    return lm_init(jax.random.PRNGKey(0), CFG)


def _requests(n=4, seed=3):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    tokens=rng.integers(0, 50, size=int(
                        rng.integers(4, 10))),
                    max_new_tokens=int(rng.integers(3, 7)))
            for i in range(n)]


# ---------------------------------------------------------------------------
# construction-time validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw,match", [
    (dict(max_new_tokens=0), "max_new_tokens"),
    (dict(temperature=-0.5), "temperature"),
    (dict(cache_len=0), "cache_len"),
    (dict(n_slots=0), "n_slots"),
    (dict(page_size=0), "page_size"),
    (dict(frame_warmup=-1), "frame_warmup"),
    (dict(use_kernel=True), "use_kernel=True requires paged=True"),
    (dict(prefix_cache=True), "prefix_cache=True requires paged=True"),
    (dict(pool_pages=8), "pool_pages requires paged=True"),
    (dict(paged=True, pool_pages=0), "pool_pages"),
])
def test_invalid_configs_raise(kw, match):
    with pytest.raises(ValueError, match=match):
        EngineConfig(**kw)


def test_valid_paged_combination():
    c = EngineConfig(paged=True, page_size=8, pool_pages=4,
                     prefix_cache=True, use_kernel=True)
    assert c.paged and c.prefix_cache and c.use_kernel


def test_replace_revalidates_and_returns_base():
    c = EngineConfig(n_slots=2)
    c2 = c.replace(paged=True, page_size=8)
    assert type(c2) is EngineConfig and c2.paged and c2.n_slots == 2
    assert not c.paged                       # frozen original untouched
    with pytest.raises(ValueError, match="prefix_cache"):
        c.replace(prefix_cache=True)         # still not paged


def test_config_is_frozen_and_hashable():
    c = EngineConfig()
    with pytest.raises(dataclasses.FrozenInstanceError):
        c.n_slots = 8
    assert hash(c) == hash(EngineConfig())


# ---------------------------------------------------------------------------
# resolve_config: the one-release loose-kwarg shim
# ---------------------------------------------------------------------------

def test_resolve_legacy_kwargs_warn_and_override():
    with pytest.warns(DeprecationWarning, match="deprecated"):
        c = resolve_config(None, {"n_slots": 2, "paged": True,
                                  "page_size": 8}, caller="t")
    assert (c.n_slots, c.paged, c.page_size) == (2, True, 8)
    # legacy kwargs override an explicit config field-by-field
    with pytest.warns(DeprecationWarning):
        c2 = resolve_config(EngineConfig(n_slots=4, max_new_tokens=7),
                            {"n_slots": 2}, caller="t")
    assert c2.n_slots == 2 and c2.max_new_tokens == 7


def test_resolve_unknown_kwarg_raises_typeerror():
    with pytest.raises(TypeError, match="unexpected keyword"):
        resolve_config(None, {"slots": 2}, caller="serve_continuous")


def test_resolve_legacy_combination_still_validated():
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="prefix_cache"):
            resolve_config(None, {"prefix_cache": True}, caller="t")


def test_resolve_no_legacy_no_warning():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert resolve_config(None, {}, caller="t") == EngineConfig()
        c = EngineConfig(n_slots=2)
        assert resolve_config(c, {}, caller="t") is c


# ---------------------------------------------------------------------------
# behavior parity through the shims (the one-release guarantee)
# ---------------------------------------------------------------------------

def test_legacy_serve_kwargs_behave_identically(params):
    reqs = _requests()
    new = serve_continuous(params, CFG, reqs,
                           EngineConfig(n_slots=2, paged=True,
                                        page_size=4))
    with pytest.warns(DeprecationWarning, match="serve_continuous"):
        old = serve_continuous(params, CFG, _requests(), n_slots=2,
                               paged=True, page_size=4)
    assert old.tokens == new.tokens
    assert old.stats["paged"] and old.stats["requests"] == len(reqs)


def test_serveconfig_shim_warns_and_generates(params):
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, 50)
    with pytest.warns(DeprecationWarning, match="ServeConfig"):
        scfg = ServeConfig(max_new_tokens=4)
    assert isinstance(scfg, EngineConfig)
    ref = generate(params, CFG, prompt, EngineConfig(max_new_tokens=4))
    out = generate(params, CFG, prompt, scfg)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_new_style_emits_no_deprecation(params):
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        serve_continuous(params, CFG, _requests(2),
                         EngineConfig(n_slots=2))
