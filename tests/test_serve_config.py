"""EngineConfig: validation and the post-shim strict signature.

The unified config is the API surface every serve entry point consumes,
so this file holds the contract: every cross-field rule fails at
construction, loose kwargs raise TypeError from the real signature (the
one-release DeprecationWarning shim and the ServeConfig subclass are
gone), and ``resolve_config`` rejects anything that is not an
EngineConfig.
"""
import dataclasses
import warnings

import jax
import numpy as np
import pytest

from repro.models import ModelConfig
from repro.models import init_params as lm_init
from repro.serve import EngineConfig, Request, generate, serve_continuous
from repro.serve.config import resolve_config

CFG = ModelConfig(name="tiny-cfg", mixer="attn", ffn="swiglu", n_layers=2,
                  d_model=32, n_heads=2, n_kv=2, head_dim=16, d_ff=64,
                  vocab=50, dtype="float32", logit_chunk=16, remat=False)


@pytest.fixture(scope="module")
def params():
    return lm_init(jax.random.PRNGKey(0), CFG)


def _requests(n=4, seed=3):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    tokens=rng.integers(0, 50, size=int(
                        rng.integers(4, 10))),
                    max_new_tokens=int(rng.integers(3, 7)))
            for i in range(n)]


# ---------------------------------------------------------------------------
# construction-time validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw,match", [
    (dict(max_new_tokens=0), "max_new_tokens"),
    (dict(temperature=-0.5), "temperature"),
    (dict(cache_len=0), "cache_len"),
    (dict(n_slots=0), "n_slots"),
    (dict(page_size=0), "page_size"),
    (dict(frame_warmup=-1), "frame_warmup"),
    (dict(use_kernel=True), "use_kernel=True requires paged=True"),
    (dict(prefix_cache=True), "prefix_cache=True requires paged=True"),
    (dict(pool_pages=8), "pool_pages requires paged=True"),
    (dict(paged=True, pool_pages=0), "pool_pages"),
    (dict(spec_k=0), "spec_k"),
    (dict(draft_prune_rate=1.0), "draft_prune_rate"),
    (dict(draft_prune_rate=-0.1), "draft_prune_rate"),
])
def test_invalid_configs_raise(kw, match):
    with pytest.raises(ValueError, match=match):
        EngineConfig(**kw)


def test_valid_paged_combination():
    c = EngineConfig(paged=True, page_size=8, pool_pages=4,
                     prefix_cache=True, use_kernel=True)
    assert c.paged and c.prefix_cache and c.use_kernel


def test_valid_speculative_combination():
    c = EngineConfig(paged=True, speculative=True, spec_k=2,
                     draft_prune_rate=0.0)
    assert c.speculative and c.spec_k == 2 and c.draft_prune_rate == 0.0


def test_replace_revalidates_and_returns_base():
    c = EngineConfig(n_slots=2)
    c2 = c.replace(paged=True, page_size=8)
    assert type(c2) is EngineConfig and c2.paged and c2.n_slots == 2
    assert not c.paged                       # frozen original untouched
    with pytest.raises(ValueError, match="prefix_cache"):
        c.replace(prefix_cache=True)         # still not paged


def test_config_is_frozen_and_hashable():
    c = EngineConfig()
    with pytest.raises(dataclasses.FrozenInstanceError):
        c.n_slots = 8
    assert hash(c) == hash(EngineConfig())


# ---------------------------------------------------------------------------
# the shim is gone: loose kwargs are real TypeErrors now
# ---------------------------------------------------------------------------

def test_loose_kwargs_raise_typeerror(params):
    with pytest.raises(TypeError, match="n_slots"):
        serve_continuous(params, CFG, _requests(2), n_slots=2)
    with pytest.raises(TypeError, match="paged"):
        serve_continuous(params, CFG, _requests(2),
                         EngineConfig(n_slots=2), paged=True)


def test_serveconfig_is_gone():
    with pytest.raises(ImportError):
        from repro.serve import ServeConfig  # noqa: F401
    with pytest.raises(ImportError):
        from repro.serve.config import ServeConfig  # noqa: F401


def test_resolve_rejects_non_config():
    with pytest.raises(TypeError, match="generate\\(\\) expects"):
        resolve_config({"n_slots": 2}, caller="generate")


def test_resolve_passthrough_no_warning():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert resolve_config(None, caller="t") == EngineConfig()
        c = EngineConfig(n_slots=2)
        assert resolve_config(c, caller="t") is c


def test_new_style_emits_no_deprecation(params):
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        serve_continuous(params, CFG, _requests(2),
                         EngineConfig(n_slots=2))


def test_generate_accepts_config(params):
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, 50)
    out = generate(params, CFG, prompt, EngineConfig(max_new_tokens=4))
    assert np.asarray(out).shape == (2, 10)
