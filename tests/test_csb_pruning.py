"""CSB projection properties (paper §3) — exact-count pruning, per-block
variable kernels, idempotence, baselines."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis — deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (
    CSBSpec, bank_balanced_project, csb_masks, csb_project, density,
    element_mask, kernel_sizes, magnitude_project, row_column_project,
)


def _rand(rng, shape):
    return rng.normal(size=shape).astype(np.float32)


def test_projection_density(rng):
    w = jnp.asarray(_rand(rng, (128, 96)))
    spec = CSBSpec(bm=32, bn=32, prune_rate=0.75)
    z = csb_project(w, spec)
    d = float(density(z))
    # kept fraction ~ (1 - 0.75); cross-point structure makes it inexact
    assert 0.15 <= d <= 0.35, d


def test_projection_idempotent(rng):
    w = jnp.asarray(_rand(rng, (64, 64)))
    spec = CSBSpec(bm=16, bn=16, prune_rate=0.6)
    z1 = csb_project(w, spec)
    z2 = csb_project(z1, spec)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), rtol=0, atol=0)


def test_cross_point_structure(rng):
    """Nonzeros must sit exactly on survivor-row x survivor-col crossings."""
    w = jnp.asarray(_rand(rng, (64, 48)))
    spec = CSBSpec(bm=16, bn=16, prune_rate=0.5)
    rm, cm = csb_masks(w, spec)
    z = np.asarray(csb_project(w, spec))
    full = np.asarray(element_mask(w.shape, spec, rm, cm))
    assert ((z != 0) <= full).all()


def test_kernel_sizes_vary(rng):
    """The paper's premise: natural sparsity is imbalanced across blocks."""
    w = jnp.asarray(_rand(rng, (128, 128)))
    m, n = kernel_sizes(w, CSBSpec(bm=32, bn=32, prune_rate=0.7))
    assert len(set(np.asarray(m).ravel().tolist())) > 1


def test_row_prune_counts_exact(rng):
    w = jnp.asarray(_rand(rng, (64, 64)))
    spec = CSBSpec(bm=16, bn=16, prune_rate=0.75)
    rm, cm = csb_masks(w, spec)
    q = 1 - np.sqrt(1 - 0.75)
    keep_r = round((1 - q) * 64)
    # per block-column the kept-row total is exact
    np.testing.assert_array_equal(
        np.asarray(rm).sum(axis=(0, 2)), keep_r)


@settings(max_examples=15, deadline=None)
@given(
    out_dim=st.sampled_from([32, 48, 64]),
    in_dim=st.sampled_from([32, 40, 64]),
    bm=st.sampled_from([8, 16]),
    rate=st.floats(0.2, 0.9),
)
def test_projection_properties(out_dim, in_dim, bm, rate):
    rng = np.random.default_rng(out_dim * in_dim + bm)
    w = jnp.asarray(_rand(rng, (out_dim, in_dim)))
    spec = CSBSpec(bm=bm, bn=bm, prune_rate=rate)
    z = csb_project(w, spec)
    # 1. only zeroing, never changing surviving values
    zn = np.asarray(z)
    wn = np.asarray(w)
    kept = zn != 0
    np.testing.assert_array_equal(zn[kept], wn[kept])
    # 2. density below the exact rounded keep bound (per-dim quantile
    # keep counts round up on small matrices, so compute it exactly)
    import math
    q = 1 - math.sqrt(1 - rate)
    br, bc = -(-out_dim // bm), -(-in_dim // bm)
    keep_r = max(round((1 - q) * br * bm), 1) / (br * bm)
    keep_c = max(round((1 - q) * bc * bm), 1) / (bc * bm)
    bound = keep_r * keep_c * (br * bm * bc * bm) / (out_dim * in_dim)
    # +0.06: kept rows/cols correlate positively across blocks (dense
    # blocks keep more of BOTH) — the cross-point density can exceed the
    # product of the marginals slightly.
    assert float(density(z)) <= bound + 0.06, (float(density(z)), bound)
    # 3. idempotent
    np.testing.assert_array_equal(np.asarray(csb_project(z, spec)), zn)


def test_magnitude_baseline_exact_count(rng):
    w = jnp.asarray(_rand(rng, (40, 50)))
    z = magnitude_project(w, 0.9)
    assert int((np.asarray(z) != 0).sum()) == round(0.1 * 2000)


def test_bank_balanced_each_bank(rng):
    w = jnp.asarray(_rand(rng, (8, 128)))
    z = np.asarray(bank_balanced_project(w, 0.75, bank=64))
    nz = (z != 0).reshape(8, 2, 64).sum(-1)
    np.testing.assert_array_equal(nz, 16)


def test_row_column_whole_matrix(rng):
    w = jnp.asarray(_rand(rng, (32, 32)))
    z = np.asarray(row_column_project(w, 0.5))
    rows = (z != 0).any(1)
    cols = (z != 0).any(0)
    # structure: zero rows/cols removed as a whole
    assert ((z != 0) <= np.outer(rows, cols)).all()
