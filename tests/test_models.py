"""Decoder substrate: family smokes, decode==forward consistency, SSD
equivalence with a naive recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (
    ModelConfig, decode_step, forward_loss, init_cache, init_params, prefill,
)
from repro.models import layers as L

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
            d_ff=128, vocab=97, dtype="float32", logit_chunk=16, remat=False)


def _batch(cfg, b=2, s=24, key=0):
    k = jax.random.PRNGKey(key)
    if cfg.n_codebooks:
        toks = jax.random.randint(k, (b, s, cfg.n_codebooks), 0, cfg.vocab)
    else:
        toks = jax.random.randint(k, (b, s), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.n_img_tokens:
        batch["img_embeds"] = jax.random.normal(
            k, (b, cfg.n_img_tokens, 1024))
    return batch


def test_prefill_decode_consistency():
    """decode after an s-token prefill must equal the (s+1)-token prefill's
    last logits — the KV cache is exact."""
    cfg = ModelConfig(name="t", mixer="attn", ffn="swiglu", **BASE)
    params = init_params(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg, b=2, s=17)
    toks = batch["tokens"]
    lg_full, _ = prefill(params, {"tokens": toks}, cfg)
    lg_pre, cache = prefill(params, {"tokens": toks[:, :-1]}, cfg)
    # grow cache by 1 slot to hold the new token
    cache = jax.tree.map(
        lambda c: jnp.pad(c, [(0, 0)] * 2 + [(0, 1)] + [(0, 0)] * (c.ndim - 3))
        if c.ndim >= 4 else c, cache)
    lg_dec, _ = decode_step(params, cache, toks[:, -1:], 16, cfg)
    np.testing.assert_allclose(np.asarray(lg_dec[:, 0]),
                               np.asarray(lg_full), rtol=2e-4, atol=2e-4)


def test_ssd_decode_matches_scan():
    """Token-by-token SSD recurrence == chunked scan over the sequence."""
    cfg = ModelConfig(name="ssm", mixer="ssd", ffn="none", d_state=8,
                      ssd_headdim=16, ssd_chunk=4, ssd_expand=2, conv_k=4,
                      **{**BASE, "n_kv": 4})
    params = init_params(jax.random.PRNGKey(3), cfg)
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(4), (b, s), 0, cfg.vocab)
    lg_full, _ = prefill(params, {"tokens": toks}, cfg)

    cache = init_cache(cfg, b, s, jnp.float32)
    lg = None
    for t in range(s):
        lg, cache = decode_step(params, cache, toks[:, t: t + 1],
                                jnp.asarray(t), cfg)
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(lg_full),
                               rtol=2e-3, atol=2e-3)


def test_mla_decode_consistency():
    cfg = ModelConfig(name="mla", mixer="mla", ffn="swiglu", kv_lora=32,
                      q_lora=24, rope_head_dim=8, **BASE)
    params = init_params(jax.random.PRNGKey(5), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(6), (2, 10), 0, cfg.vocab)
    lg_full, _ = prefill(params, {"tokens": toks}, cfg)
    cache = init_cache(cfg, 2, 10, jnp.float32)
    lg = None
    for t in range(10):
        lg, cache = decode_step(params, cache, toks[:, t: t + 1],
                                jnp.asarray(t), cfg)
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(lg_full),
                               rtol=2e-3, atol=2e-3)


def test_blockwise_attention_matches_naive(rng):
    b, s, h, d = 2, 33, 4, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, 2, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, 2, d)).astype(np.float32))
    out = L.blockwise_attention(q, k, v, causal=True, q_chunk=8, kv_chunk=8)
    # naive reference
    kk = jnp.repeat(k, 2, axis=2)
    vv = jnp.repeat(v, 2, axis=2)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(d)
    mask = np.tril(np.ones((s, s), bool))
    sc = jnp.where(mask[None, None], sc, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(sc, -1), vv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_blockwise_window_attention(rng):
    b, s, h, d = 1, 32, 2, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    out = L.blockwise_attention(q, k, v, causal=True, window=8,
                                q_chunk=8, kv_chunk=8)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(d)
    ii = np.arange(s)
    mask = (ii[:, None] >= ii[None, :]) & (ii[:, None] - ii[None, :] < 8)
    sc = jnp.where(mask[None, None], sc, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(sc, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_moe_routes_all_tokens_with_capacity(rng):
    cfg = ModelConfig(name="moe", mixer="attn", ffn="moe", n_experts=4,
                      top_k=2, n_shared=0, moe_dff=32, moe_chunk=32,
                      capacity_factor=2.0, **BASE)
    params = init_params(jax.random.PRNGKey(7), cfg)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 16, 64))
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    y = L.moe_apply(lp["ffn"], x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    # generous capacity => output differs from zero for (almost) all tokens
    norms = np.linalg.norm(np.asarray(y), axis=-1)
    assert (norms > 1e-6).mean() > 0.95


def test_loss_label_masking():
    cfg = ModelConfig(name="t", mixer="attn", ffn="swiglu", **BASE)
    params = init_params(jax.random.PRNGKey(9), cfg)
    batch = _batch(cfg, b=2, s=16)
    l1 = forward_loss(params, batch, cfg)
    masked = dict(batch)
    masked["labels"] = batch["labels"].at[:, :8].set(-1)
    l2 = forward_loss(params, masked, cfg)
    assert np.isfinite(float(l1)) and np.isfinite(float(l2))
    assert abs(float(l1) - float(l2)) > 1e-6  # masking changes the loss
