"""Fuzz/property suite for the page allocator + pool-aware scheduler.

The oracle is ``PagePool.check()`` — it asserts, in one pass, that no
page is leaked, double-freed, or aliased across two live slots, that
stale table entries are cleared, and that reservations never exceed
pool capacity. The fuzz driver below replays the *exact* engine
protocol (submit -> admit/reserve -> ensure(prompt) -> started ->
per-step ensure -> advance -> release-in-finish) over hundreds of
random arrival/finish traces, running the oracle plus occupancy
reconciliation after every event.

A second sweep drives the same protocol with ``prefix_cache=True``:
requests are generated with deliberate shared prefixes (plus a small
vocab so accidental sharing happens too), admissions go one-at-a-time
through ``try_reserve -> cow_if_needed -> ensure -> register_prefix``
exactly as the prefix engine does, and requests keep decoding while
later arrivals share (and CoW off of) their prompt pages. Sharing
breaks the trie-less reconciliation identities — ``available()`` no
longer equals ``n_pages - reserved_total()`` and mapped table entries
stop being globally unique — so the prefix traces reconcile through
``check()``'s refcount-conservation/aliasing oracle instead, and drain
the trie with ``drop_prefix_cache()`` before the terminal free-list
asserts.

Shrunk failure cases found while developing the allocator are committed
at the bottom as plain regression tests, so they keep running even if
the random sweep changes shape.
"""
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis — deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

import numpy as np
import pytest

from repro.serve import (
    PagePool, Request, SlotScheduler, pages_for, simulate_admission,
)

N_SWEEPS = 40
TRACES_PER_SWEEP = 6        # 240 generated traces total (>= 200)


# ---------------------------------------------------------------------------
# the engine-faithful trace driver
# ---------------------------------------------------------------------------

def _reconcile(pool: PagePool) -> None:
    """Occupancy counters must agree with the free list at all times."""
    pool.check()
    assert pool.allocated_total() == pool.n_pages - len(pool._free)
    assert 0 <= pool.reserved_total() <= pool.n_pages
    assert pool.available() == pool.n_pages - pool.reserved_total()
    table = np.asarray(pool.device_table())
    assert table.shape == (pool.n_slots, pool.max_pages)
    assert ((table >= 0) & (table <= pool.scratch_page)).all()
    # mapped (non-scratch) entries are globally unique
    mapped = table[table < pool.scratch_page]
    assert len(mapped) == len(set(mapped.tolist()))


def run_trace(rng: np.random.Generator, n_slots: int, page_size: int,
              n_pages: int, max_pages: int, n_reqs: int) -> dict:
    if min(n_pages, max_pages) * page_size < 2:
        page_size = 2       # smallest request (1 prompt + 1 new) must fit
    pool = PagePool(page_size, n_pages, n_slots, max_pages)
    sched = SlotScheduler(n_slots, pool=pool)
    cap_tokens = min(n_pages, max_pages) * page_size
    reqs = []
    for i in range(n_reqs):
        total = int(rng.integers(2, cap_tokens + 1))
        plen = int(rng.integers(1, total))
        reqs.append(Request(
            rid=i, tokens=np.zeros(plen, np.int32),
            max_new_tokens=total - plen,
            arrival=int(rng.integers(0, 3 * n_reqs))))
    for r in reqs:
        sched.submit(r)
    _reconcile(pool)

    guard = sum(r.max_new_tokens + r.arrival for r in reqs) \
        + 10 * n_reqs + 10
    while sched.has_work():
        for slot, req in sched.admit():
            _reconcile(pool)
            pool.ensure(slot, req.prompt_len)
            _reconcile(pool)
            sched.started(slot, int(rng.integers(0, 100)))
            _reconcile(pool)
        active = sched.active_mask()
        if not active.any():
            sched.idle_tick()
            guard -= 1
            assert guard > 0, "trace did not terminate (idle)"
            continue
        pos = sched.positions()
        for i in np.flatnonzero(active):
            pool.ensure(int(i), int(pos[i]) + 1)
            _reconcile(pool)
        pool.tick()
        sched.advance(rng.integers(0, 100, size=n_slots))
        _reconcile(pool)
        guard -= 1
        assert guard > 0, "trace did not terminate"

    # terminal reconciliation: the trace drained everything
    assert pool.allocated_total() == 0, "pages leaked at end of trace"
    assert pool.reserved_total() == 0
    assert sorted(pool._free) == list(range(n_pages))
    assert len(sched.results) == n_reqs
    for r in reqs:
        assert len(sched.results[r.rid]) == r.max_new_tokens
    return sched.stats()


# ---------------------------------------------------------------------------
# prefix-cache trace driver (sharing-aware reconciliation)
# ---------------------------------------------------------------------------

def _reconcile_prefix(pool: PagePool) -> None:
    """Sharing-aware reconciliation. With a trie attached, pages may be
    mapped by several slots at once and ``available()`` folds in
    reclaimable trie pages, so the trie-less identities of
    :func:`_reconcile` do not hold — refcount conservation, aliasing-
    only-via-trie and write isolation all live inside ``check()``."""
    pool.check()
    assert pool.allocated_total() == pool.n_pages - len(pool._free)
    assert 0 <= pool.reserved_total() <= pool.n_slots * pool.max_pages
    # outstanding <= free + evictable (checked inside check()) bounds this
    assert 0 <= pool.available() <= pool.n_pages
    table = np.asarray(pool.device_table())
    assert table.shape == (pool.n_slots, pool.max_pages)
    assert ((table >= 0) & (table <= pool.scratch_page)).all()


def _prefix_reqs(rng: np.random.Generator, n_reqs: int, cap_tokens: int
                 ) -> list[Request]:
    """Shared-prefix request mix: most requests reuse a random-length
    prefix of an earlier prompt (divergence lands mid-page as often as
    on a boundary) and append a fresh tail; the rest are fresh. Tokens
    come from a tiny vocab so *accidental* prefix collisions happen on
    top of the deliberate ones."""
    bases: list[np.ndarray] = []
    reqs = []
    for i in range(n_reqs):
        total = int(rng.integers(2, cap_tokens + 1))
        plen = int(rng.integers(1, total))
        if bases and rng.random() < 0.7:
            base = bases[int(rng.integers(len(bases)))]
            keep = int(rng.integers(1, min(plen, len(base)) + 1))
            toks = np.concatenate([
                base[:keep],
                rng.integers(0, 7, size=plen - keep)]).astype(np.int32)
        else:
            toks = rng.integers(0, 7, size=plen).astype(np.int32)
        if len(bases) < 4 or rng.random() < 0.3:
            bases.append(toks)
        reqs.append(Request(rid=i, tokens=toks,
                            max_new_tokens=total - plen,
                            arrival=int(rng.integers(0, 3 * n_reqs))))
    return reqs


def run_prefix_trace(rng: np.random.Generator, n_slots: int,
                     page_size: int, n_pages: int, max_pages: int,
                     n_reqs: int) -> dict:
    """The engine's prefix-cache admission protocol over a random trace:
    one-at-a-time admission (so a prompt registered this step is
    matchable by the very next admission), ``cow_if_needed`` before the
    first write past the shared span, ``register_prefix`` after the
    prompt is fully ensured, decode growth + release as usual."""
    if min(n_pages, max_pages) * page_size < 2:
        page_size = 2       # smallest request (1 prompt + 1 new) must fit
    pool = PagePool(page_size, n_pages, n_slots, max_pages,
                    prefix_cache=True)
    sched = SlotScheduler(n_slots, pool=pool)
    cap_tokens = min(n_pages, max_pages) * page_size
    reqs = _prefix_reqs(rng, n_reqs, cap_tokens)
    for r in reqs:
        sched.submit(r)
    _reconcile_prefix(pool)

    guard = sum(r.max_new_tokens + r.arrival for r in reqs) \
        + 10 * n_reqs + 10
    while sched.has_work():
        while True:
            batch = sched.admit(limit=1)
            if not batch:
                break
            [(slot, req)] = batch
            info = pool.shared_info(slot)
            assert info is not None      # try_reserve path always records
            # at least one suffix token is always left to prefill, and
            # CoW is needed exactly when the suffix starts inside the
            # shared span
            assert info.suffix_start < req.prompt_len
            assert info.needs_cow == (
                info.shared_pages > 0
                and info.suffix_start < info.shared_pages * page_size)
            pair = pool.cow_if_needed(slot)
            assert (pair is not None) == info.needs_cow
            if pair is not None:
                src, dst = pair
                assert src != dst and 0 <= dst < pool.n_pages
            _reconcile_prefix(pool)
            pool.ensure(slot, req.prompt_len)
            pool.register_prefix(slot, np.asarray(req.tokens).reshape(-1))
            _reconcile_prefix(pool)
            sched.started(slot, int(rng.integers(0, 100)))
            _reconcile_prefix(pool)
        active = sched.active_mask()
        if not active.any():
            sched.idle_tick()
            guard -= 1
            assert guard > 0, "prefix trace did not terminate (idle)"
            continue
        pos = sched.positions()
        for i in np.flatnonzero(active):
            pool.ensure(int(i), int(pos[i]) + 1)
            _reconcile_prefix(pool)
        pool.tick()
        sched.advance(rng.integers(0, 100, size=n_slots))
        _reconcile_prefix(pool)
        guard -= 1
        assert guard > 0, "prefix trace did not terminate"

    # terminal: only the trie holds pages (that is the cache working);
    # dropping it must drain the pool completely
    assert pool.reserved_total() == 0
    assert pool.allocated_total() == pool.trie_pages()
    pool.drop_prefix_cache()
    pool.check()
    assert pool.allocated_total() == 0, "pages leaked past the trie"
    assert pool.trie_pages() == 0
    assert sorted(pool._free) == list(range(n_pages))
    assert len(sched.results) == n_reqs
    for r in reqs:
        assert len(sched.results[r.rid]) == r.max_new_tokens
    return sched.stats()


# ---------------------------------------------------------------------------
# disaggregated handoff trace driver (delayed accept, ISSUE 9)
# ---------------------------------------------------------------------------

def run_handoff_trace(rng: np.random.Generator, n_slots: int,
                      page_size: int, n_pages: int, max_pages: int,
                      n_reqs: int, prefix: bool) -> dict:
    """The disaggregated engine's event order: a whole admission batch
    RESERVES decode-tier slots first (``reserve``/``try_reserve``
    through the scheduler), then each slot's pages are mapped only when
    its prefill handoff is accepted — ``cow_if_needed ->
    ensure(prompt) -> register_prefix -> started`` as one event, in a
    RANDOM order across the batch. Several slots sit reserved-but-
    unmapped at once; refcount conservation must hold through that
    window, which is exactly what ``DecodeTier.accept`` relies on.
    (Under the prefix cache the engine admits one-at-a-time so each
    trie registration is visible to the next match — mirrored here.)"""
    if min(n_pages, max_pages) * page_size < 2:
        page_size = 2       # smallest request (1 prompt + 1 new) must fit
    pool = PagePool(page_size, n_pages, n_slots, max_pages,
                    prefix_cache=prefix)
    sched = SlotScheduler(n_slots, pool=pool)
    cap_tokens = min(n_pages, max_pages) * page_size
    if prefix:
        reqs = _prefix_reqs(rng, n_reqs, cap_tokens)
    else:
        reqs = []
        for i in range(n_reqs):
            total = int(rng.integers(2, cap_tokens + 1))
            plen = int(rng.integers(1, total))
            reqs.append(Request(
                rid=i, tokens=np.zeros(plen, np.int32),
                max_new_tokens=total - plen,
                arrival=int(rng.integers(0, 3 * n_reqs))))
    for r in reqs:
        sched.submit(r)
    recon = _reconcile_prefix if prefix else _reconcile
    recon(pool)

    pending: list[tuple[int, Request]] = []   # handoff queue

    def accept_one(idx: int = 0):
        slot, req = pending.pop(idx)
        if prefix:
            info = pool.shared_info(slot)
            assert info is not None
            pair = pool.cow_if_needed(slot)
            assert (pair is not None) == info.needs_cow
            recon(pool)
        pool.ensure(slot, req.prompt_len)
        if prefix:
            pool.register_prefix(slot,
                                 np.asarray(req.tokens).reshape(-1))
        recon(pool)
        sched.started(slot, int(rng.integers(0, 100)))
        recon(pool)

    guard = sum(r.max_new_tokens + r.arrival for r in reqs) \
        + 10 * len(reqs) + 10
    while sched.has_work():
        while True:
            batch = sched.admit(limit=1)
            if not batch:
                break
            pending.append(batch[0])
            recon(pool)                 # reserved, nothing mapped yet
            if prefix:
                # the trie registration must be visible before the next
                # admission matches against it (the engine admits
                # one-at-a-time under the prefix cache)
                accept_one()
        # drain the whole handoff queue in random order before stepping
        # (every slot in the batch sits reserved-but-unmapped until its
        # own accept runs)
        while pending:
            accept_one(int(rng.integers(len(pending))))
        active = sched.active_mask()
        if not active.any():
            sched.idle_tick()
            guard -= 1
            assert guard > 0, "handoff trace did not terminate (idle)"
            continue
        pos = sched.positions()
        for i in np.flatnonzero(active):
            pool.ensure(int(i), int(pos[i]) + 1)
            recon(pool)
        pool.tick()
        sched.advance(rng.integers(0, 100, size=n_slots))
        recon(pool)
        guard -= 1
        assert guard > 0, "handoff trace did not terminate"

    assert not pending
    assert pool.reserved_total() == 0
    if prefix:
        assert pool.allocated_total() == pool.trie_pages()
        pool.drop_prefix_cache()
        pool.check()
    assert pool.allocated_total() == 0, "pages leaked past the handoff"
    assert sorted(pool._free) == list(range(pool.n_pages))
    assert len(sched.results) == len(reqs)
    for r in reqs:
        assert len(sched.results[r.rid]) == r.max_new_tokens
    return sched.stats()


# ---------------------------------------------------------------------------
# speculative trace driver (rollback via truncate, ISSUE 10)
# ---------------------------------------------------------------------------

def run_spec_trace(rng: np.random.Generator, n_slots: int, page_size: int,
                   n_pages: int, max_pages: int, n_reqs: int,
                   k: int) -> dict:
    """The speculative engine's event order: each round ensures pages
    for the whole verify span (frontier + k + 1, capped at the slot's
    lifetime), then a random acceptance length rolls the slot back with
    ``truncate`` — tail pages ensured for rejected positions must come
    back to the free list immediately, with ``check()`` holding after
    every event."""
    if min(n_pages, max_pages) * page_size < 2:
        page_size = 2       # smallest request (1 prompt + 1 new) must fit
    pool = PagePool(page_size, n_pages, n_slots, max_pages)
    sched = SlotScheduler(n_slots, pool=pool)
    cap_tokens = min(n_pages, max_pages) * page_size
    reqs = []
    for i in range(n_reqs):
        total = int(rng.integers(2, cap_tokens + 1))
        plen = int(rng.integers(1, total))
        reqs.append(Request(
            rid=i, tokens=np.zeros(plen, np.int32),
            max_new_tokens=total - plen,
            arrival=int(rng.integers(0, 3 * n_reqs))))
    for r in reqs:
        sched.submit(r)
    _reconcile(pool)

    guard = sum(r.max_new_tokens + r.arrival for r in reqs) \
        + 10 * n_reqs + 10
    while sched.has_work():
        for slot, req in sched.admit():
            pool.ensure(slot, req.prompt_len)
            _reconcile(pool)
            sched.started(slot, int(rng.integers(0, 100)))
            _reconcile(pool)
        active = sched.active_mask()
        if not active.any():
            sched.idle_tick()
            guard -= 1
            assert guard > 0, "spec trace did not terminate (idle)"
            continue
        pos = sched.positions()
        remaining = np.asarray([
            0 if s is None else s.remaining for s in sched._slots])
        # verify-span ensure: frontier + k + 1 capped at lifetime tokens
        for i in np.flatnonzero(active):
            pool.ensure(int(i), int(min(pos[i] + k + 1,
                                        pos[i] + remaining[i])))
            _reconcile(pool)
        pool.tick()
        committed = {}
        for i in np.flatnonzero(active):
            k_eff = min(k, int(remaining[i]) - 1)
            n = int(rng.integers(1, k_eff + 2))       # 1..k_eff+1
            committed[int(i)] = [int(t) for t in
                                 rng.integers(0, 100, size=n)]
            pool.truncate(int(i), int(pos[i]) + n)
            _reconcile(pool)
        sched.advance_spec(committed)
        _reconcile(pool)
        guard -= 1
        assert guard > 0, "spec trace did not terminate"

    assert pool.allocated_total() == 0, "pages leaked at end of trace"
    assert pool.reserved_total() == 0
    assert sorted(pool._free) == list(range(n_pages))
    assert len(sched.results) == n_reqs
    for r in reqs:
        assert len(sched.results[r.rid]) == r.max_new_tokens
    return sched.stats()


@pytest.mark.parametrize("sweep", range(N_SWEEPS))
def test_fuzz_random_traces(sweep):
    rng = np.random.default_rng(7919 * sweep + 13)
    for _ in range(TRACES_PER_SWEEP):
        n_slots = int(rng.integers(1, 6))
        page_size = int(rng.integers(1, 9))
        max_pages = int(rng.integers(1, 9))
        # pool ranges from starved (1 page) to ample
        n_pages = int(rng.integers(1, n_slots * max_pages + 2))
        n_reqs = int(rng.integers(1, 13))
        run_trace(rng, n_slots, page_size, n_pages, max_pages, n_reqs)


@pytest.mark.parametrize("sweep", range(N_SWEEPS))
def test_fuzz_spec_traces(sweep):
    """240 speculative traces: verify-span ensure followed by a random-
    acceptance truncate every round, oracle after every event."""
    rng = np.random.default_rng(6700417 * sweep + 17)
    for _ in range(TRACES_PER_SWEEP):
        n_slots = int(rng.integers(1, 6))
        page_size = int(rng.integers(1, 9))
        max_pages = int(rng.integers(1, 9))
        n_pages = int(rng.integers(1, n_slots * max_pages + 2))
        n_reqs = int(rng.integers(1, 13))
        k = int(rng.integers(1, 6))
        run_spec_trace(rng, n_slots, page_size, n_pages, max_pages,
                       n_reqs, k)


def test_fuzz_starved_pool_stalls_but_completes():
    """Heavy contention: pool far smaller than slots x max_pages — every
    request still completes, admission stalls are counted, and the pool
    never over-admits (checked inside the driver)."""
    rng = np.random.default_rng(99)
    stats = run_trace(rng, n_slots=4, page_size=4, n_pages=3,
                      max_pages=3, n_reqs=16)
    assert stats["requests"] == 16
    assert stats["paging"]["peak_pages"] <= 3


@pytest.mark.parametrize("sweep", range(N_SWEEPS))
def test_fuzz_prefix_traces(sweep):
    """240 shared-prefix traces through the prefix-cache protocol, with
    check() + sharing-aware reconciliation after every event."""
    rng = np.random.default_rng(104729 * sweep + 29)
    hits = 0
    for _ in range(TRACES_PER_SWEEP):
        n_slots = int(rng.integers(1, 6))
        page_size = int(rng.integers(1, 9))
        max_pages = int(rng.integers(1, 9))
        n_pages = int(rng.integers(1, n_slots * max_pages + 2))
        n_reqs = int(rng.integers(1, 13))
        stats = run_prefix_trace(rng, n_slots, page_size, n_pages,
                                 max_pages, n_reqs)
        hits += stats["prefix_hits"]
    # the generator builds shared prefixes on purpose — a sweep that
    # never hits the trie means the protocol under test went dead
    assert hits > 0


@pytest.mark.parametrize("sweep", range(N_SWEEPS))
@pytest.mark.parametrize("prefix", [False, True],
                         ids=["plain", "prefix"])
def test_fuzz_handoff_traces(sweep, prefix):
    """240 traces x {plain, prefix} through the disaggregated handoff
    protocol: batch reservation at admission, mapping delayed to
    randomly-ordered accepts, check() after every event (refcount
    conservation across the reserved-but-unmapped window)."""
    rng = np.random.default_rng(15485863 * sweep + 41)
    for _ in range(TRACES_PER_SWEEP):
        n_slots = int(rng.integers(1, 6))
        page_size = int(rng.integers(1, 9))
        max_pages = int(rng.integers(1, 9))
        n_pages = int(rng.integers(1, n_slots * max_pages + 2))
        n_reqs = int(rng.integers(1, 13))
        run_handoff_trace(rng, n_slots, page_size, n_pages,
                          max_pages, n_reqs, prefix)


def test_handoff_prefix_traces_actually_share():
    """An ample pool + the shared-prefix generator must register trie
    hits through the handoff protocol — a zero would mean the delayed
    accept path stopped registering prompts."""
    rng = np.random.default_rng(77)
    hits = 0
    for _ in range(8):
        stats = run_handoff_trace(rng, n_slots=4, page_size=4,
                                  n_pages=32, max_pages=8, n_reqs=10,
                                  prefix=True)
        hits += stats["prefix_hits"]
    assert hits > 0


def test_handoff_starved_pool_completes():
    """Handoff protocol under heavy contention: delayed accepts on a
    pool far below slots x max_pages still conserve every page."""
    rng = np.random.default_rng(515151)
    stats = run_handoff_trace(rng, n_slots=4, page_size=4, n_pages=3,
                              max_pages=3, n_reqs=16, prefix=False)
    assert stats["requests"] == 16
    assert stats["paging"]["peak_pages"] <= 3


def test_fuzz_prefix_starved_pool_recycles_trie():
    """Prefix cache under heavy contention: the trie must surrender its
    retained pages to reservations (LRU leaf reclaim) and every request
    still completes with exact page conservation."""
    rng = np.random.default_rng(424242)
    stats = run_prefix_trace(rng, n_slots=4, page_size=2, n_pages=4,
                             max_pages=4, n_reqs=20)
    assert stats["requests"] == 20
    assert stats["paging"]["peak_pages"] <= 4
    assert stats["paging"]["trie_evictions"] > 0


# ---------------------------------------------------------------------------
# allocator unit properties (hypothesis / fallback)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(page_size=st.integers(1, 16), n_tokens=st.integers(0, 257))
def test_pages_for_is_exact_ceiling(page_size, n_tokens):
    p = pages_for(n_tokens, page_size)
    assert p * page_size >= n_tokens
    assert n_tokens == 0 or (p - 1) * page_size < n_tokens
    assert pages_for(0, page_size) == 0


@settings(max_examples=40, deadline=None)
@given(page_size=st.integers(1, 8), n_pages=st.integers(1, 24),
       max_pages=st.integers(1, 8))
def test_reserve_admits_exactly_to_capacity(page_size, n_pages, max_pages):
    """Greedy single-page reservations fill the pool to exactly
    min(n_pages, slots) and not one page further."""
    n_slots = n_pages + 1
    pool = PagePool(page_size, n_pages, n_slots, max_pages)
    admitted = 0
    for slot in range(n_slots):
        if pool.can_admit(page_size):
            pool.reserve(slot, page_size)
            admitted += 1
        pool.check()
    assert admitted == min(n_pages, n_slots)
    assert not pool.can_admit(1)
    assert pool.available() == n_pages - admitted


def test_double_reserve_raises():
    pool = PagePool(4, 8, 2, 4)
    pool.reserve(0, 8)
    with pytest.raises(RuntimeError):
        pool.reserve(0, 4)


def test_ensure_beyond_reservation_raises():
    pool = PagePool(4, 8, 2, 4)
    pool.reserve(0, 8)          # 2 pages
    pool.ensure(0, 8)
    with pytest.raises(RuntimeError):
        pool.ensure(0, 9)       # would need a 3rd page
    pool.check()


def test_release_is_idempotent_and_exact():
    pool = PagePool(2, 4, 2, 2)
    pool.reserve(0, 4)
    pool.ensure(0, 3)
    pages = pool.slot_pages(0)
    assert len(pages) == 2
    freed = pool.release(0)
    assert freed == pages
    pool.check()
    assert pool.release(0) == []        # double release frees nothing
    pool.check()
    assert pool.available() == 4


def test_truncate_frees_exact_tail_and_keeps_boundary():
    pool = PagePool(4, 8, 2, 4)
    pool.reserve(0, 16)
    pool.ensure(0, 14)                  # 4 pages mapped
    pages = pool.slot_pages(0)
    assert len(pages) == 4
    freed = pool.truncate(0, 6)         # needs 2 pages
    assert sorted(freed) == sorted(pages[2:])
    assert pool.slot_pages(0) == pages[:2]
    pool.check()
    # mid-page rollback within the same page count frees nothing: the
    # boundary page stays (its tail positions are masked, not zeroed)
    assert pool.truncate(0, 5) == []
    assert pool.slot_pages(0) == pages[:2]
    pool.check()
    # re-growing after a rollback maps fresh pages from the free list
    pool.ensure(0, 9)
    assert len(pool.slot_pages(0)) == 3
    pool.check()


def test_truncate_beyond_length_raises():
    pool = PagePool(4, 8, 2, 4)
    pool.reserve(0, 8)
    pool.ensure(0, 8)
    with pytest.raises(ValueError, match="beyond"):
        pool.truncate(0, 9)
    pool.check()


def test_truncate_into_shared_span_raises():
    """A slot whose prompt pages are shared via the trie must never roll
    back into the shared span — those pages belong to other readers."""
    pool = PagePool(4, 8, 2, 4, prefix_cache=True)
    sched = SlotScheduler(2, pool=pool)
    a = np.asarray([1, 2, 3, 4, 5, 6, 7, 8], np.int32)
    sched.submit(Request(rid=0, tokens=a, max_new_tokens=4))
    sched.submit(Request(rid=1, tokens=np.asarray(
        list(a[:4]) + [9] * 4, np.int32), max_new_tokens=4, arrival=1))
    [(s0, r0)] = sched.admit(limit=1)
    pool.ensure(s0, r0.prompt_len)
    pool.register_prefix(s0, r0.tokens)
    sched.started(s0, 0)
    sched.advance(np.zeros(2, np.int64))
    [(s1, r1)] = sched.admit(limit=1)
    assert pool.shared_info(s1).shared_pages == 1
    pool.cow_if_needed(s1)
    pool.ensure(s1, r1.prompt_len)
    with pytest.raises(ValueError, match="shared"):
        pool.truncate(s1, 3)            # inside the shared first page
    pool.truncate(s1, 4)                # exactly the shared span: ok
    pool.check()


def test_over_capacity_request_rejected_at_submit():
    pool = PagePool(4, 4, 2, 4)         # 16-token pool
    sched = SlotScheduler(2, pool=pool)
    with pytest.raises(ValueError):
        sched.submit(Request(rid=0, tokens=np.zeros(20, np.int32),
                             max_new_tokens=8))
    # max_pages binds even when the pool itself is larger
    pool2 = PagePool(4, 32, 2, 2)       # 8 tokens per slot max
    sched2 = SlotScheduler(2, pool=pool2)
    with pytest.raises(ValueError):
        sched2.submit(Request(rid=0, tokens=np.zeros(6, np.int32),
                              max_new_tokens=6))


def test_constructor_validation():
    for bad in [(0, 4, 2, 2), (4, 0, 2, 2), (4, 4, 0, 2), (4, 4, 2, 0)]:
        with pytest.raises(ValueError):
            PagePool(*bad)


# ---------------------------------------------------------------------------
# shrunk regression cases (committed from fuzz failures during bring-up)
# ---------------------------------------------------------------------------

def test_regression_spec_trace_tiny_pool_truncates_cleanly():
    """Shrunk speculative shape: a 2-page pool with k far beyond the
    pool's span — every round over-ensures to the cap and rolls back;
    nothing may leak across the repeated grow/shrink cycles."""
    rng = np.random.default_rng(1)
    stats = run_spec_trace(rng, n_slots=1, page_size=2, n_pages=2,
                           max_pages=2, n_reqs=3, k=4)
    assert stats["requests"] == 3


def test_regression_one_page_pool_serial_reuse():
    """Smallest interesting pool: 1 page, 1 slot. Two requests must run
    strictly serially, the second reusing the page the first freed."""
    rng = np.random.default_rng(0)
    stats = run_trace(rng, n_slots=1, page_size=2, n_pages=1,
                      max_pages=1, n_reqs=2)
    assert stats["requests"] == 2
    assert stats["peak_active"] == 1


def test_regression_prefill_only_request_releases_reservation():
    """max_new_tokens == 1 finishes inside started() — the reservation
    (and any prompt pages) must come back without an advance() ever
    touching the slot."""
    pool = PagePool(4, 4, 2, 4)
    sched = SlotScheduler(2, pool=pool)
    sched.submit(Request(rid=0, tokens=np.zeros(5, np.int32),
                         max_new_tokens=1))
    [(slot, req)] = sched.admit()
    pool.ensure(slot, req.prompt_len)
    assert pool.allocated_total() == 2
    assert sched.started(slot, 7) is False      # finished at prefill
    pool.check()
    assert pool.allocated_total() == 0 and pool.reserved_total() == 0
    assert sched.results[0] == [7]


def test_regression_blocked_head_preserves_fifo():
    """A big head request that does not currently fit must stall
    admission (strict FIFO — later small requests do NOT jump it), then
    get admitted once the running request frees its pages."""
    pool = PagePool(2, 4, 2, 4)                 # 8-token pool
    sched = SlotScheduler(2, pool=pool)
    sched.submit(Request(rid=0, tokens=np.zeros(2, np.int32),
                         max_new_tokens=2))     # 2 pages
    sched.submit(Request(rid=1, tokens=np.zeros(4, np.int32),
                         max_new_tokens=4))     # 4 pages: blocked
    sched.submit(Request(rid=2, tokens=np.zeros(1, np.int32),
                         max_new_tokens=1))     # 1 page: would fit
    first = sched.admit()
    assert [r.rid for _, r in first] == [0]     # head blocked -> rid 2 waits
    assert sched.page_stalls == 1
    slot, req = first[0]
    pool.ensure(slot, req.prompt_len)
    sched.started(slot, 0)
    sched.advance(np.zeros(2, np.int64))        # rid 0 finishes, pages free
    nxt = sched.admit()
    # rid 1 takes the whole pool; rid 2 stays FIFO-blocked behind it
    assert [r.rid for _, r in nxt] == [1]
    slot1, req1 = nxt[0]
    pool.ensure(slot1, req1.prompt_len)
    sched.started(slot1, 0)
    for _ in range(3):
        sched.advance(np.zeros(2, np.int64))    # drain rid 1
    last = sched.admit()
    assert [r.rid for _, r in last] == [2]
    pool.check()


def test_regression_simulate_admission_pool_stats():
    """simulate_admission must reconcile with a pool attached and report
    paging telemetry; a pool sized below slots x max keeps peak_pages at
    its capacity bound."""
    reqs = [Request(rid=i, tokens=np.zeros(3, np.int32), max_new_tokens=5,
                    arrival=0) for i in range(6)]
    pool = PagePool(4, 4, 4, 2)
    stats = simulate_admission(4, reqs, pool=pool)
    assert stats["requests"] == 6
    assert stats["paging"]["peak_pages"] <= 4
    assert stats["paging"]["internal_fragmentation"] >= 0.0
    pool.check()
    assert pool.allocated_total() == 0


def test_regression_prefix_cow_against_live_reader():
    """Mid-decode divergence: request B shares A's prompt pages and
    CoWs its divergence page while A is STILL decoding through the
    shared original — the copy must not disturb A's mapping and both
    slots must release cleanly."""
    pool = PagePool(4, 8, 2, 4, prefix_cache=True)
    sched = SlotScheduler(2, pool=pool)
    a = [1, 2, 3, 4, 5, 6, 7, 8]                    # two whole pages
    sched.submit(Request(rid=0, tokens=np.asarray(a, np.int32),
                         max_new_tokens=6))
    sched.submit(Request(rid=1,
                         tokens=np.asarray(a[:6] + [9, 9], np.int32),
                         max_new_tokens=2, arrival=1))
    [(s0, r0)] = sched.admit(limit=1)
    assert pool.cow_if_needed(s0) is None           # nothing shared yet
    pool.ensure(s0, r0.prompt_len)
    pool.register_prefix(s0, r0.tokens)
    sched.started(s0, 0)
    a_pages = pool.slot_pages(s0)
    sched.advance(np.zeros(2, np.int64))            # A decoding, B arrives
    [(s1, r1)] = sched.admit(limit=1)
    info = pool.shared_info(s1)
    assert info.shared_tokens == 6 and info.needs_cow
    src, dst = pool.cow_if_needed(s1)
    assert src == a_pages[1] and dst not in a_pages
    pool.ensure(s1, r1.prompt_len)
    pool.register_prefix(s1, r1.tokens)
    _reconcile_prefix(pool)
    assert pool.slot_pages(s0) == a_pages           # A's view untouched
    assert pool.slot_pages(s1)[0] == a_pages[0]     # page 0 truly shared
    sched.started(s1, 0)
    for _ in range(5):
        for i in np.flatnonzero(sched.active_mask()):
            pool.ensure(int(i), int(sched.positions()[i]) + 1)
        sched.advance(np.zeros(2, np.int64))
        _reconcile_prefix(pool)
    assert len(sched.results) == 2
    assert pool.reserved_total() == 0
    pool.drop_prefix_cache()
    assert pool.allocated_total() == 0


def test_regression_prefix_identical_prompt_serial_one_slot():
    """The same prompt resubmitted after the first request finished: the
    trie retains its pages past release, the re-hit caps suffix_start at
    prompt_len - 1 (one token always re-prefills) and CoWs the page that
    token lands in."""
    rid = 0

    def run_one(pool, sched, toks, max_new):
        nonlocal rid
        sched.submit(Request(rid=rid, tokens=toks, max_new_tokens=max_new))
        rid += 1
        [(slot, req)] = sched.admit(limit=1)
        info = pool.shared_info(slot)
        pool.cow_if_needed(slot)
        pool.ensure(slot, req.prompt_len)
        pool.register_prefix(slot, toks)
        _reconcile_prefix(pool)
        if sched.started(slot, 0):
            while sched.active_mask().any():
                pool.ensure(slot, int(sched.positions()[slot]) + 1)
                sched.advance(np.zeros(1, np.int64))
                _reconcile_prefix(pool)
        return info

    pool = PagePool(4, 8, 1, 4, prefix_cache=True)
    sched = SlotScheduler(1, pool=pool)
    toks = np.asarray([1, 2, 3, 4, 5, 6, 7, 8], np.int32)
    first = run_one(pool, sched, toks, 3)
    assert first.shared_pages == 0
    assert pool.trie_pages() == 2                   # retained past release
    second = run_one(pool, sched, toks, 3)
    assert second.shared_tokens == 8                # full match
    assert second.suffix_start == 7                 # capped at plen - 1
    assert second.needs_cow and pool.cow_copies == 1
    assert sched.prefix_hits == 1
    pool.drop_prefix_cache()
    pool.check()
    assert pool.allocated_total() == 0


def test_regression_prefix_cow_cost_must_not_starve_admission():
    """Shrunk from the prefix fuzz (sweep 14): on a tight pool a partial
    trie match can make the shared plan need MORE pages than no sharing
    (the CoW copy costs a page while the pinned match stops being
    evictable). try_reserve must retreat to the unshared plan instead of
    stalling the FIFO head forever."""
    pool = PagePool(4, 4, 1, 4, prefix_cache=True)
    sched = SlotScheduler(1, pool=pool)
    # seed the trie with one page, then free the slot
    sched.submit(Request(rid=0, tokens=np.asarray([1, 2, 3, 4], np.int32),
                         max_new_tokens=1))
    [(slot, req)] = sched.admit(limit=1)
    pool.ensure(slot, req.prompt_len)
    pool.register_prefix(slot, req.tokens)
    assert sched.started(slot, 0) is False          # done at prefill
    assert pool.trie_pages() == 1 and len(pool._free) == 3
    # head matches 1 token of the cached page and needs the WHOLE pool:
    # shared plan = 1 pinned + 4 private > capacity; unshared plan = 4
    sched.submit(Request(rid=1,
                         tokens=np.asarray([1] + [9] * 7, np.int32),
                         max_new_tokens=5))
    admitted = sched.admit(limit=1)
    assert admitted, "admission starved by an unaffordable shared plan"
    [(slot, req)] = admitted
    info = pool.shared_info(slot)
    assert info.shared_pages == 0 and not info.needs_cow
    assert pool._reserved[slot] == 4                # full unshared need
    assert pool.cow_if_needed(slot) is None
    pool.ensure(slot, req.prompt_len)
    _reconcile_prefix(pool)
    # growing to the full reservation drains the free list and reclaims
    # the (unpinned) trie page
    pool.ensure(slot, req.prompt_len + req.max_new_tokens)
    _reconcile_prefix(pool)
    assert pool.trie_evictions == 1 and pool.trie_pages() == 0


def test_regression_simulate_admission_prefix_pool():
    """simulate_admission drives the prefix protocol too (cow -> ensure
    -> register) — shared-system-prompt replay must reconcile and report
    the sharing counters."""
    sys_p = list(range(8))
    reqs = [Request(rid=i, tokens=np.asarray(sys_p + [10 + i], np.int32),
                    max_new_tokens=4, arrival=i) for i in range(4)]
    pool = PagePool(4, 16, 2, 4, prefix_cache=True)
    stats = simulate_admission(2, reqs, pool=pool)
    assert stats["requests"] == 4
    assert stats["prefix_hits"] == 3                # all but the first
    assert stats["shared_pages"] >= 6               # 2 whole pages each
    pool.check()
    assert pool.reserved_total() == 0
    assert pool.allocated_total() == pool.trie_pages()
