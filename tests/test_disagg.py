"""Disaggregated prefill/decode tiers + multi-replica router.

Acceptance (ISSUE 9): ``serve_disaggregated`` and a 2-replica
``Router`` are token-for-token identical to single-engine
``serve_continuous`` on the same skewed arrival trace — unsharded and
on 1x8 / 2x4 host meshes (mesh cases need 8 devices; CI sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8``). The dryrun
half holds ``simulate_replicas`` to reporting p50/p99 TTFT/latency and
SLO attainment for every routing policy.
"""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.models import ModelConfig
from repro.models import init_params as lm_init
from repro.serve import (
    EngineConfig, Request, Router, route, serve_continuous,
    serve_disaggregated, simulate_replicas,
)
from repro.serve.router import make_arrival_trace

CFG = ModelConfig(name="tiny-disagg", mixer="attn", ffn="swiglu",
                  n_layers=2, d_model=32, n_heads=2, n_kv=2, head_dim=16,
                  d_ff=64, vocab=50, dtype="float32", logit_chunk=16,
                  remat=False)
PAGED = EngineConfig(n_slots=2, paged=True, page_size=4)

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def params():
    return lm_init(jax.random.PRNGKey(0), CFG)


def _skewed_trace(seed=5, n=8):
    """Mixed lengths + staggered arrivals: slot eviction/refill and the
    handoff queue both get exercised."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(9, 14)) if i % 3 == 0 else \
            int(rng.integers(4, 8))
        reqs.append(Request(rid=i,
                            tokens=rng.integers(0, 50, size=plen),
                            max_new_tokens=int(rng.integers(3, 7)),
                            arrival=(i // 2) * 3))
    return reqs


def _shared_trace(seed=7, n=6):
    rng = np.random.default_rng(seed)
    sys_p = rng.integers(0, 50, size=9)      # divergence mid-page
    return [Request(rid=i,
                    tokens=np.concatenate(
                        [sys_p,
                         rng.integers(0, 50,
                                      size=int(rng.integers(1, 5)))]),
                    max_new_tokens=4, arrival=(i // 3) * 2)
            for i in range(n)]


# ---------------------------------------------------------------------------
# disagg parity (acceptance)
# ---------------------------------------------------------------------------

def test_disagg_matches_single_engine(params):
    reqs = _skewed_trace()
    single = serve_continuous(params, CFG, reqs, PAGED)
    dis = serve_disaggregated(params, CFG, reqs, PAGED)
    assert dis.tokens == single.tokens
    assert dis.stats["disagg"] and dis.stats["paged"]
    # one handoff per request; every surviving handoff mapped pages
    assert dis.stats["handoffs"] == len(reqs)
    assert dis.stats["handoff_pages"] > 0
    assert dis.stats["prefill_tokens"] >= sum(
        r.prompt_len for r in reqs)          # bucket padding counts


def test_disagg_prefix_sharing_parity(params):
    reqs = _shared_trace()
    cfg = PAGED.replace(prefix_cache=True)
    single = serve_continuous(params, CFG, reqs, cfg)
    dis = serve_disaggregated(params, CFG, reqs, cfg)
    assert dis.tokens == single.tokens
    assert dis.stats["prefix_hits"] == single.stats["prefix_hits"] > 0
    # partial prefill through the handoff really skipped shared tokens
    off = serve_disaggregated(params, CFG, _shared_trace(), PAGED)
    assert dis.stats["prefill_tokens"] < off.stats["prefill_tokens"]


def test_disagg_nongreedy_parity_same_rng(params):
    """Temperature > 0: both engines split the SAME rng in the same
    order, so even sampled tokens agree."""
    reqs = _skewed_trace(seed=9, n=5)
    cfg = PAGED.replace(temperature=0.8)
    key = jax.random.PRNGKey(42)
    single = serve_continuous(params, CFG, reqs, cfg, rng=key)
    dis = serve_disaggregated(params, CFG, reqs, cfg, rng=key)
    assert dis.tokens == single.tokens


@needs8
@pytest.mark.parametrize("shape", [(1, 8), (2, 4)],
                         ids=["mesh1x8", "mesh2x4"])
def test_disagg_sharded_matches_unsharded(params, shape):
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(shape),
                ("data", "model"))
    reqs = _skewed_trace(seed=6, n=6)
    ref = serve_disaggregated(params, CFG, reqs, PAGED)
    res = serve_disaggregated(params, CFG, reqs, PAGED, mesh=mesh)
    assert res.stats["sharded"]
    assert res.tokens == ref.tokens


def test_disagg_requires_paged(params):
    with pytest.raises(ValueError, match="paged=True"):
        serve_disaggregated(params, CFG, _skewed_trace(n=2),
                            EngineConfig(n_slots=2))


def test_disagg_empty_trace(params):
    res = serve_disaggregated(params, CFG, [], PAGED)
    assert res.tokens == {} and res.stats["handoffs"] == 0


def test_disagg_finish_at_prefill(params):
    """max_new_tokens=1 requests finish at the handoff boundary —
    nothing is ever mapped into the decode pool for them."""
    rng = np.random.default_rng(2)
    reqs = [Request(rid=i, tokens=rng.integers(0, 50, size=5),
                    max_new_tokens=1) for i in range(3)]
    single = serve_continuous(params, CFG, reqs, PAGED)
    dis = serve_disaggregated(params, CFG, reqs, PAGED)
    assert dis.tokens == single.tokens
    assert dis.stats["handoffs"] == 3 and dis.stats["handoff_pages"] == 0


# ---------------------------------------------------------------------------
# router parity (acceptance)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["continuous", "disagg"])
def test_router_two_replicas_matches_single_engine(params, engine):
    reqs = _skewed_trace()
    single = serve_continuous(params, CFG, reqs, PAGED)
    router = Router(2, PAGED, policy="least_loaded", engine=engine)
    res = router.serve(params, CFG, reqs)
    assert res.tokens == single.tokens       # every rid, every token
    assert res.stats["replicas"] == 2
    assert sum(res.stats["replica_requests"]) == len(reqs)
    assert all(n > 0 for n in res.stats["replica_requests"])


@needs8
def test_router_parity_sharded_2x4(params):
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4),
                ("data", "model"))
    reqs = _skewed_trace(seed=11, n=6)
    single = serve_continuous(params, CFG, reqs, PAGED, mesh=mesh)
    res = Router(2, PAGED).serve(params, CFG, reqs, mesh=mesh)
    assert res.tokens == single.tokens
    assert all(s["sharded"] for s in res.stats["per_replica"])


def test_route_policies_and_validation():
    reqs = [Request(rid=i, tokens=np.zeros(4, np.int64),
                    max_new_tokens=4, arrival=i) for i in range(6)]
    rr = route(reqs, 3, policy="round_robin")
    assert [len(a) for a in rr] == [2, 2, 2]
    ll = route(reqs, 3, policy="least_loaded", n_slots=1)
    assert sum(len(a) for a in ll) == 6
    with pytest.raises(ValueError, match="policy"):
        route(reqs, 2, policy="weighted")
    with pytest.raises(ValueError, match="replica"):
        route(reqs, 0)
    with pytest.raises(ValueError, match="entries"):
        route(reqs, 2, step_time_us=[1.0, 2.0, 3.0])


def test_least_loaded_avoids_slow_replica():
    """A 10x slower replica should receive (far) fewer requests."""
    reqs = [Request(rid=i, tokens=np.zeros(4, np.int64),
                    max_new_tokens=8, arrival=0) for i in range(8)]
    out = route(reqs, 2, policy="least_loaded", n_slots=2,
                step_time_us=[1.0, 10.0])
    assert len(out[0]) > len(out[1])


# ---------------------------------------------------------------------------
# the trace-driven SLO dryrun
# ---------------------------------------------------------------------------

def test_request_deadline_default_none():
    r = Request(rid=0, tokens=np.zeros(4, np.int64), max_new_tokens=2)
    assert r.deadline_us is None


def test_simulate_replicas_reports_both_policies():
    trace = make_arrival_trace(np.random.default_rng(3), 20,
                               mean_gap_steps=0.5, deadline_slack=2.0,
                               step_time_us=2.0)
    assert all(r.deadline_us is not None for r in trace)
    for pol in ("round_robin", "least_loaded"):
        s = simulate_replicas(trace, 2, policy=pol, n_slots=2,
                              step_time_us=2.0)
        assert s["policy"] == pol and s["requests"] == 20
        assert s["ttft_us"]["p50"] <= s["ttft_us"]["p99"]
        assert s["latency_us"]["p50"] <= s["latency_us"]["p99"]
        assert s["deadlines"] == 20
        assert 0.0 <= s["slo_attainment"] <= 1.0
        assert len(s["per_replica"]) == 2


def test_simulate_replicas_no_deadlines_attainment_none():
    trace = make_arrival_trace(np.random.default_rng(4), 6)
    s = simulate_replicas(trace, 2, n_slots=2)
    assert s["slo_attainment"] is None and s["deadlines"] == 0
    # latency percentiles still reported (TTFT >= 1 step always)
    assert s["latency_us"]["p99"] >= s["ttft_us"]["p50"] > 0


def test_heterogeneous_fleet_latency_scales():
    """Same trace, one replica 5x slower: fleet p99 must exceed the
    uniform-fast fleet's (the cost model actually reaches the SLO)."""
    trace = make_arrival_trace(np.random.default_rng(5), 16,
                               mean_gap_steps=0.25)
    fast = simulate_replicas(trace, 2, n_slots=2, step_time_us=1.0)
    mixed = simulate_replicas(trace, 2, n_slots=2,
                              step_time_us=[1.0, 5.0])
    assert mixed["latency_us"]["p99"] >= fast["latency_us"]["p99"]
