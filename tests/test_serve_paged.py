"""Paged serve engine: token parity, admission wins, prefill bucketing.

Parity is the acceptance bar: paged ``serve_continuous`` must equal the
contiguous-cache ``generate`` loop token-for-token — unsharded and on
1x8 / 2x4 host meshes (the mesh cases need 8 devices; CI sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8``, locally they
skip). The admission test shows the memory win: a mixed-length trace
runs at higher concurrency through the paged pool than a contiguous
engine given the SAME token budget can reach.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.models import (
    ModelConfig, decode_step_paged, init_paged_cache,
)
from repro.models import init_params as lm_init
from repro.serve import (
    EngineConfig, PagePool, Request, bucket_len, generate, pages_for,
    serve_continuous,
)
from repro.serve import engine as serve_engine

CFG = ModelConfig(name="tiny-paged", mixer="attn", ffn="swiglu",
                  n_layers=2, d_model=32, n_heads=2, n_kv=2, head_dim=16,
                  d_ff=64, vocab=50, dtype="float32", logit_chunk=16,
                  remat=False)

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def params():
    return lm_init(jax.random.PRNGKey(0), CFG)


def _requests(prompts, max_new, arrivals=None):
    arrivals = arrivals or [0] * len(prompts)
    return [Request(rid=i, tokens=np.asarray(p), max_new_tokens=m,
                    arrival=a)
            for i, (p, m, a) in enumerate(zip(prompts, max_new, arrivals))]


def _ref_tokens(params, prompt, n_new):
    out = generate(params, CFG, jnp.asarray(prompt)[None],
                   EngineConfig(max_new_tokens=n_new))
    return np.asarray(out)[0, len(prompt):]


# ---------------------------------------------------------------------------
# token-for-token parity (acceptance)
# ---------------------------------------------------------------------------

def test_paged_matches_generate_mixed_lengths(params):
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, 50, size=n) for n in (4, 8, 5, 7, 6)]
    max_new = [4, 6, 5, 4, 6]
    reqs = _requests(prompts, max_new, arrivals=[0, 0, 3, 6, 6])
    res = serve_continuous(params, CFG, reqs,
                           EngineConfig(n_slots=2, paged=True,
                                        page_size=4))
    assert res.stats["paged"] and res.stats["bucketed_prefill"]
    for i, p in enumerate(prompts):
        np.testing.assert_array_equal(
            res.tokens[i], _ref_tokens(params, p, max_new[i]),
            err_msg=f"request {i}")
    pg = res.stats["paging"]
    assert pg["peak_pages"] <= pg["n_pages"]
    assert 0.0 <= pg["internal_fragmentation"] < 1.0


def test_paged_evict_refill_single_slot_no_leak(params):
    """Two very different requests forced through the SAME slot (and
    recycled pages): each must decode exactly as it does alone."""
    rng = np.random.default_rng(3)
    p0 = rng.integers(0, 50, size=9)
    p1 = rng.integers(0, 50, size=4)
    res = serve_continuous(params, CFG, _requests([p0, p1], [5, 6]),
                           EngineConfig(n_slots=1, paged=True,
                                        page_size=4))
    np.testing.assert_array_equal(res.tokens[0], _ref_tokens(params, p0, 5))
    np.testing.assert_array_equal(res.tokens[1], _ref_tokens(params, p1, 6))


@needs8
@pytest.mark.parametrize("shape", [(1, 8), (2, 4)],
                         ids=["mesh1x8", "mesh2x4"])
def test_paged_sharded_matches_unsharded(params, shape):
    """Acceptance: paged sharded continuous batching == unsharded greedy
    output token-for-token on 1x8 and 2x4 host meshes."""
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(shape),
                ("data", "model"))
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, 50, size=n) for n in (5, 9, 6, 7)]
    max_new = [5, 4, 6, 5]
    reqs = _requests(prompts, max_new, arrivals=[0, 0, 2, 4])
    res = serve_continuous(params, CFG, reqs,
                           EngineConfig(n_slots=2, paged=True,
                                        page_size=4), mesh=mesh)
    assert res.stats["sharded"] and res.stats["paged"]
    for i, p in enumerate(prompts):
        np.testing.assert_array_equal(
            res.tokens[i], _ref_tokens(params, p, max_new[i]),
            err_msg=f"mesh {shape} request {i}")


def test_paged_vector_pos_matches_scalar(params):
    """decode_step_paged with a (B,) position vector == the scalar-pos
    trace at the same depth, logits and pool contents both."""
    n_slots, psz = 3, 4
    pool = PagePool(psz, 6, n_slots, 2)
    for s in range(n_slots):
        pool.reserve(s, 8)
        pool.ensure(s, 5)
    table = pool.device_table()
    cache = init_paged_cache(CFG, 6, psz, n_slots, jnp.float32)
    # non-trivial pool contents so the gather path is actually exercised
    cache = jax.tree.map(
        lambda a: jax.random.normal(
            jax.random.PRNGKey(a.size % 97), a.shape).astype(a.dtype)
        if jnp.issubdtype(a.dtype, jnp.floating) else a, cache)
    toks = jax.random.randint(jax.random.PRNGKey(2), (n_slots, 1), 0, 50)
    lg_s, c_s = decode_step_paged(params, cache, toks, 4, table, CFG)
    lg_v, c_v = decode_step_paged(params, cache, toks,
                                  jnp.full((n_slots,), 4, jnp.int32),
                                  table, CFG)
    np.testing.assert_allclose(np.asarray(lg_v), np.asarray(lg_s),
                               rtol=1e-6, atol=1e-6)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6), c_v, c_s)


# ---------------------------------------------------------------------------
# the memory win (acceptance): paged admits what contiguous must queue
# ---------------------------------------------------------------------------

def test_paged_outadmits_contiguous_on_same_budget(params):
    """One long + four short requests. Budget = 80 cache tokens. The
    contiguous engine can only carve that into 2 max-length slots
    (80 // 40) and must queue; the paged pool reserves per-request
    pages and runs 3+ requests concurrently — same tokens out."""
    psz = 8
    rng = np.random.default_rng(11)
    long_p = rng.integers(0, 50, size=8)
    shorts = [rng.integers(0, 50, size=8) for _ in range(4)]
    prompts = [long_p] + shorts
    max_new = [32, 8, 8, 8, 8]          # totals: 40, 16 x4
    cache_len = 40
    budget_tokens = 80
    assert budget_tokens == 2 * cache_len == 10 * psz

    reqs = _requests(prompts, max_new)
    paged = serve_continuous(
        params, CFG, reqs,
        EngineConfig(n_slots=4, paged=True, page_size=psz,
                     cache_len=cache_len,
                     pool_pages=budget_tokens // psz))
    contig = serve_continuous(
        params, CFG, _requests(prompts, max_new),
        EngineConfig(n_slots=budget_tokens // cache_len,
                     cache_len=cache_len))
    for i, p in enumerate(prompts):
        ref = _ref_tokens(params, p, max_new[i])
        np.testing.assert_array_equal(paged.tokens[i], ref)
        np.testing.assert_array_equal(contig.tokens[i], ref)
    # the same budget holds >2 concurrent requests only when paged
    assert contig.stats["peak_active"] == 2
    assert paged.stats["peak_active"] >= 3
    assert paged.stats["paging"]["peak_pages"] <= budget_tokens // psz


# ---------------------------------------------------------------------------
# prefill bucketing: O(log max_len) compiles, token-identical output
# ---------------------------------------------------------------------------

def test_bucket_len_shape():
    assert [bucket_len(n) for n in (1, 7, 8, 9, 16, 17, 100)] == \
        [8, 8, 8, 16, 16, 32, 128]


def test_prefill_bucketing_bounds_recompiles():
    """32 distinct prompt lengths in [1, 64] must compile at most
    log2(64)+1 prefill executables (jit cache-miss counter on the
    shared prefill), and at most one decode step."""
    cfg = ModelConfig(name="tiny-paged-recompile", mixer="attn",
                      ffn="swiglu", n_layers=2, d_model=32, n_heads=2,
                      n_kv=2, head_dim=16, d_ff=64, vocab=50,
                      dtype="float32", logit_chunk=16, remat=False)
    params = lm_init(jax.random.PRNGKey(1), cfg)
    max_len = 64
    lens = list(range(1, 65, 2))        # 32 distinct lengths
    assert len(set(lens)) == 32
    rng = np.random.default_rng(7)
    reqs = _requests([rng.integers(0, 50, size=n) for n in lens],
                     [2] * len(lens))
    res = serve_continuous(params, cfg, reqs,
                           EngineConfig(n_slots=4, paged=True,
                                        page_size=8))
    assert res.stats["requests"] == 32
    jt = serve_engine._jitted(cfg, None)
    compiled = jt["prefill"]._cache_size()
    bound = int(math.log2(max_len)) + 1
    assert compiled <= bound, (compiled, bound)
    # exactly the pow2 buckets the trace touches, nothing per-length
    assert compiled == len({bucket_len(n) for n in lens})
    assert all(fn._cache_size() == 1 for fn in jt["steps"].values())


def test_bucket_padding_never_changes_tokens(params):
    """Same trace with bucketing on vs off: identical sampled tokens
    (right padding is invisible under causal masking)."""
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, 50, size=n) for n in (3, 9, 13, 6)]
    max_new = [5, 4, 3, 6]
    on = serve_continuous(params, CFG, _requests(prompts, max_new),
                          EngineConfig(n_slots=2, paged=True, page_size=4,
                                       bucket_prompts=True))
    off = serve_continuous(params, CFG, _requests(prompts, max_new),
                           EngineConfig(n_slots=2, paged=True,
                                        page_size=4,
                                        bucket_prompts=False))
    assert on.stats["bucketed_prefill"] and not off.stats[
        "bucketed_prefill"]
    assert on.tokens == off.tokens
    for i, p in enumerate(prompts):
        np.testing.assert_array_equal(
            on.tokens[i], _ref_tokens(params, p, max_new[i]))


def test_paged_rejects_oversized_request(params):
    reqs = _requests([np.zeros(6, np.int64)], [8])
    with pytest.raises(ValueError):
        serve_continuous(params, CFG, reqs,
                         EngineConfig(n_slots=1, cache_len=10,
                                      paged=True))
    # fits cache_len but not the (smaller) pool
    with pytest.raises(ValueError):
        serve_continuous(params, CFG, _requests([np.zeros(6, np.int64)],
                                                [8]),
                         EngineConfig(n_slots=2, cache_len=16, paged=True,
                                      page_size=4, pool_pages=2))


def test_pages_for_consistency_with_engine(params):
    """Page accounting in stats matches pages_for arithmetic."""
    reqs = _requests([np.arange(5) % 50], [3])
    res = serve_continuous(params, CFG, reqs,
                           EngineConfig(n_slots=1, paged=True,
                                        page_size=4))
    # one request: peak pages == pages for its deepest position
    assert res.stats["paging"]["peak_pages"] == pages_for(5 + 3, 4)
