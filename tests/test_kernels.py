"""Pallas CSB-MVM kernel vs the pure-jnp oracle — shape/dtype sweeps in
interpret mode (per-kernel allclose deliverable)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CSBSpec, csb_masks, csb_project, padded_csb_from_dense
from repro.kernels.ops import csb_matvec
from repro.kernels.ref import csb_mvm_ref, densify


def make_padded(rng, shape, bm, bn, rate, pad_to=8, dtype=jnp.float32):
    w = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    spec = CSBSpec(bm=bm, bn=bn, prune_rate=rate)
    z = csb_project(w, spec)
    rm, cm = csb_masks(w, spec)
    return padded_csb_from_dense(
        np.asarray(z), bm, bn, pad_to=pad_to, dtype=dtype,
        row_mask=np.asarray(rm), col_mask=np.asarray(cm)), np.asarray(z)


@pytest.mark.parametrize("shape,bm,bn", [
    ((32, 32), 16, 16),
    ((64, 48), 16, 16),
    ((48, 64), 16, 32),
    ((128, 96), 32, 32),
    ((40, 24), 8, 8),      # non-divisible -> padded grid
])
@pytest.mark.parametrize("rate", [0.3, 0.75])
def test_kernel_matches_ref_shapes(rng, shape, bm, bn, rate):
    p, z = make_padded(rng, shape, bm, bn, rate)
    x = jnp.asarray(rng.normal(size=(5, shape[1])).astype(np.float32))
    y_ref = csb_mvm_ref(p, x)
    y_ker = csb_matvec(p, x)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    # and both match the dense masked matmul
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(x) @ z.T,
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtypes(rng, dtype):
    p, z = make_padded(rng, (64, 64), 16, 16, 0.5, dtype=dtype)
    x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32)).astype(dtype)
    y_ref = csb_mvm_ref(p, x)
    y_ker = csb_matvec(p, x)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(y_ker, np.float32), np.asarray(y_ref, np.float32),
        rtol=tol, atol=tol)


def test_kernel_batch_shapes(rng):
    p, _ = make_padded(rng, (48, 32), 16, 16, 0.5)
    for batch_shape in [(), (1,), (3,), (2, 5)]:
        x = jnp.asarray(
            rng.normal(size=(*batch_shape, 32)).astype(np.float32))
        y = csb_matvec(p, x)
        assert y.shape == (*batch_shape, 48)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(csb_mvm_ref(p, x)), rtol=1e-5,
            atol=1e-5)


def test_kernel_group_fusion(rng):
    """group > 1 fuses several blocks per grid step — same results."""
    p, _ = make_padded(rng, (64, 64), 16, 16, 0.5)
    x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
    y1 = csb_matvec(p, x, group=1)
    y2 = csb_matvec(p, x, group=2)
    y4 = csb_matvec(p, x, group=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), rtol=1e-5)


def test_kernel_batch_tiles(rng):
    p, _ = make_padded(rng, (32, 32), 16, 16, 0.5)
    x = jnp.asarray(rng.normal(size=(13, 32)).astype(np.float32))
    for bt in (8, 16):
        y = csb_matvec(p, x, batch_tile=bt)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(csb_mvm_ref(p, x)), rtol=1e-5,
            atol=1e-5)


def test_empty_blocks(rng):
    """Blocks fully pruned away (m=0 or n=0) must contribute zero."""
    z = np.zeros((32, 32), np.float32)
    z[:16, :16] = rng.normal(size=(16, 16))  # only one block alive
    p = padded_csb_from_dense(z, 16, 16)
    x = jnp.asarray(rng.normal(size=(3, 32)).astype(np.float32))
    y = csb_matvec(p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) @ z.T,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(densify(p)), z, atol=0)
