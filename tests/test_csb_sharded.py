"""Mesh-sharded CSB execution: planner balance + sharded-matvec parity.

The parity tests need 8 host devices — CI sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; without it they
skip (conftest deliberately leaves device count alone). The planner
tests are pure numpy and always run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding

from repro.core import CSBSpec, csb_masks, csb_project, padded_csb_from_dense
from repro.core.csb_format import ShardedCSB
from repro.dist.csb_partition import (
    block_row_cycles, partition_padded, plan_block_rows,
)
from repro.dist.rules import csb_shard_specs

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _mesh18() -> Mesh:
    return Mesh(np.asarray(jax.devices()[:8]).reshape(1, 8),
                ("data", "model"))


def make_padded(rng, shape, bm, bn, rate, pad_to=8):
    w = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    spec = CSBSpec(bm=bm, bn=bn, prune_rate=rate)
    z = csb_project(w, spec)
    rm, cm = csb_masks(w, spec)
    p = padded_csb_from_dense(
        np.asarray(z), bm, bn, pad_to=pad_to,
        row_mask=np.asarray(rm), col_mask=np.asarray(cm))
    return p, np.asarray(z)


def skewed_padded(rng):
    """The skewed-blocks fixture: 32 block-rows where the first 8 are
    unpruned (dense) and the rest keep ~25% of lanes — the per-row cycle
    profile of a diagonal-dense/gate-banded RNN matrix (paper §6.3.2)."""
    bm = bn = 16
    z = np.zeros((512, 256), np.float32)
    z[:128] = rng.normal(size=(128, 256))          # 8 dense block-rows
    light = rng.normal(size=(384, 256)).astype(np.float32)
    mask = np.zeros((384, 256), bool)
    mask[::4, ::4] = True                          # 4x4 survivors per block
    z[128:] = np.where(mask, light, 0.0)
    return padded_csb_from_dense(z, bm, bn), z


# ---------------------------------------------------------------------------
# planner (no devices needed)
# ---------------------------------------------------------------------------

def test_skewed_fixture_balance(rng):
    p, _ = skewed_padded(rng)
    cyc = block_row_cycles(p)
    assert len(cyc) == 32 and cyc[:8].min() > cyc[8:].max()
    equal = plan_block_rows(cyc, 8, policy="equal")
    greedy = plan_block_rows(cyc, 8, policy="greedy")
    assert equal.imbalance >= 1.5, equal.as_dict()
    assert greedy.imbalance <= 1.1, greedy.as_dict()
    # both are true partitions of the row set
    for plan in (equal, greedy):
        rows = sorted(r for dev in plan.assignment for r in dev)
        assert rows == list(range(32))
        # planned cycles conserve total work
        assert sum(plan.device_cycles) == int(cyc.sum())


def test_plan_policies_and_errors():
    cyc = [5, 5, 5, 5]
    eq = plan_block_rows(cyc, 4, policy="equal")
    assert eq.imbalance == 1.0 and eq.n_dev == 4
    with pytest.raises(ValueError):
        plan_block_rows(cyc, 4, policy="nope")
    with pytest.raises(ValueError):
        plan_block_rows(cyc, 0)
    # more devices than rows: empty devices allowed
    plan = plan_block_rows([3, 2], 4)
    assert sum(len(a) for a in plan.assignment) == 2


def test_split_block_rows_roundtrip(rng):
    p, _ = make_padded(rng, (96, 64), 16, 16, 0.5)     # br=6
    plan = plan_block_rows(block_row_cycles(p), 4)
    s = p.split_block_rows(plan.assignment)
    assert isinstance(s, ShardedCSB)
    assert s.n_dev == 4 and s.grid == p.grid and s.block == p.block
    # pad rows carry zero workload
    br, bc = p.grid
    total = int(np.asarray(p.m).astype(np.int64) @ np.asarray(p.n))
    sh = int((np.asarray(s.m).astype(np.int64) * np.asarray(s.n)).sum())
    assert sh == total
    perm = s.output_permutation()
    assert len(set(perm.tolist())) == br * 16          # injective over rows
    assert perm.max() < s.n_dev * s.rows_per_dev * 16
    with pytest.raises(ValueError):
        p.split_block_rows(((0, 1), (1, 2)))           # not a partition


def test_csb_shard_specs_guards(rng):
    p, _ = make_padded(rng, (96, 64), 16, 16, 0.5)
    _, s = partition_padded(p, 8)

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 1, "model": 8}

    specs = csb_shard_specs(s, FakeMesh())
    assert specs.vals[0] == "model" and specs.m[0] == "model"

    class Mismatch:
        axis_names = ("data", "model")
        shape = {"data": 1, "model": 4}

    specs = csb_shard_specs(s, Mismatch())        # width mismatch -> replicate
    assert specs.vals[0] is None
    specs = csb_shard_specs(p, FakeMesh())        # unsplit -> replicate
    assert specs.vals[0] is None

    # mixed tree: dense leaves keep param_specs' name-based placement
    # (row-parallel 'wo' shards its INPUT dim), CSB leaves shard their
    # device axis
    import jax as _jax

    tree = {"wo": _jax.ShapeDtypeStruct((64, 32), jnp.float32), "csb": s}
    specs = csb_shard_specs(tree, FakeMesh())
    assert tuple(specs["wo"]) == ("model", None)
    assert specs["csb"].vals[0] == "model"


# ---------------------------------------------------------------------------
# sharded matvec parity (8 host devices)
# ---------------------------------------------------------------------------

@needs8
@pytest.mark.parametrize("shape,bm,bn,rate", [
    ((176, 96), 16, 16, 0.5),    # br=11: uneven rows across 8 devices
    ((48, 64), 16, 16, 0.5),     # br=3: fewer block-rows than devices
    ((128, 128), 16, 32, 0.9),   # pad-lane-heavy (deep pruning, pad_to=8)
    ((40, 24), 8, 8, 0.3),       # non-divisible dims -> padded grid
])
def test_sharded_matches_unsharded_and_dense(rng, shape, bm, bn, rate):
    from repro.kernels.csb_sharded import csb_matvec_sharded
    from repro.kernels.ops import csb_matvec

    p, z = make_padded(rng, shape, bm, bn, rate)
    plan, s = partition_padded(p, 8)
    x = jnp.asarray(rng.normal(size=(5, shape[1])).astype(np.float32))
    y_ref = csb_matvec(p, x)
    y_sh = csb_matvec_sharded(s, x, mesh=_mesh18())
    np.testing.assert_allclose(np.asarray(y_sh), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_sh), np.asarray(x) @ z.T,
                               rtol=1e-4, atol=1e-4)


@needs8
def test_sharded_skewed_fixture_parity(rng):
    """Acceptance fixture: parity AND balanced placement together."""
    from repro.kernels.csb_sharded import csb_matvec_sharded
    from repro.kernels.ops import csb_matvec

    p, z = skewed_padded(rng)
    plan, s = partition_padded(p, 8)
    assert plan.imbalance <= 1.1
    x = jnp.asarray(rng.normal(size=(3, 256)).astype(np.float32))
    y_sh = csb_matvec_sharded(s, x, mesh=_mesh18())
    np.testing.assert_allclose(np.asarray(y_sh),
                               np.asarray(csb_matvec(p, x)),
                               rtol=1e-5, atol=1e-5)


@needs8
def test_sharded_batch_shapes_and_device_put(rng):
    from repro.kernels.csb_sharded import csb_matvec_sharded
    from repro.kernels.ops import csb_matvec

    mesh = _mesh18()
    p, _ = make_padded(rng, (96, 64), 16, 16, 0.5)
    _, s = partition_padded(p, 8)
    # place the shards explicitly with the derived specs (what a serve
    # path would do once, at load time)
    specs = csb_shard_specs(s, mesh)
    s = jax.tree_util.tree_map(
        lambda leaf, spec: jax.device_put(leaf, NamedSharding(mesh, spec)),
        s, specs)
    for batch_shape in [(), (3,), (2, 5)]:
        x = jnp.asarray(
            rng.normal(size=(*batch_shape, 64)).astype(np.float32))
        y = csb_matvec_sharded(s, x, mesh=mesh)
        assert y.shape == (*batch_shape, 96)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(csb_matvec(p, x)),
                                   rtol=1e-5, atol=1e-5)


@needs8
def test_sharded_data_model_mesh_parity(rng):
    """2x4 mesh: batch stays data-sharded while block-rows split over
    the model axis — same numbers as the local kernel."""
    from repro.kernels.csb_sharded import csb_matvec_sharded
    from repro.kernels.ops import csb_matvec

    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4),
                ("data", "model"))
    p, z = make_padded(rng, (96, 64), 16, 16, 0.5)
    _, s = partition_padded(p, 4)
    for batch in (1, 5, 16):          # odd + non-dp-divisible included
        x = jnp.asarray(rng.normal(size=(batch, 64)).astype(np.float32))
        y = csb_matvec_sharded(s, x, mesh=mesh)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(csb_matvec(p, x)),
                                   rtol=1e-5, atol=1e-5)


def test_overlap_chunking_helpers():
    """Chunk bounds partition the rows; overlap=1 reorder is identity."""
    from repro.kernels.csb_sharded import _chunk_bounds, _chunk_order

    assert _chunk_bounds(4, 2) == [(0, 2), (2, 4)]
    assert _chunk_bounds(5, 2) == [(0, 3), (3, 5)]
    assert _chunk_bounds(3, 8) == [(0, 1), (1, 2), (2, 3)]  # clamped
    for rpd, ov in [(4, 1), (5, 2), (11, 3)]:
        bounds = _chunk_bounds(rpd, ov)
        assert bounds[0][0] == 0 and bounds[-1][1] == rpd
        assert all(a[1] == b[0] for a, b in zip(bounds, bounds[1:]))
    ident = _chunk_order(8, 4, 16, _chunk_bounds(4, 1))
    np.testing.assert_array_equal(ident, np.arange(8 * 4 * 16))
    # any chunking is a permutation of the gather positions
    ord2 = _chunk_order(8, 5, 16, _chunk_bounds(5, 2))
    assert sorted(ord2.tolist()) == list(range(8 * 5 * 16))


@needs8
@pytest.mark.parametrize("overlap", [1, 2, 3, 4])
def test_collective_overlap_parity(rng, overlap):
    """The collective-matmul pipeline must match the serial compute-
    then-gather output BITWISE for every chunking — rows are
    independent, only the compute/collective interleaving changes."""
    from repro.kernels.csb_sharded import csb_matvec_sharded
    from repro.kernels.ops import csb_matvec

    p, _ = skewed_padded(rng)                  # br=32 -> rpd=4 on 8 dev
    _, s = partition_padded(p, 8)
    x = jnp.asarray(rng.normal(size=(5, 256)).astype(np.float32))
    y_serial = csb_matvec_sharded(s, x, mesh=_mesh18(), overlap=1)
    y = csb_matvec_sharded(s, x, mesh=_mesh18(), overlap=overlap)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_serial))
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(csb_matvec(p, x)),
                               rtol=1e-5, atol=1e-5)


@needs8
def test_collective_overlap_uneven_rows_2x4(rng):
    """Uneven rows-per-device (rpd with a remainder chunk) on a 2x4
    data x model mesh: chunked gathers + folded unpermute still restore
    the original row order exactly."""
    from repro.kernels.csb_sharded import csb_matvec_sharded
    from repro.kernels.ops import csb_matvec

    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4),
                ("data", "model"))
    p, _ = make_padded(rng, (176, 96), 16, 16, 0.5)    # br=11 over 4 dev
    _, s = partition_padded(p, 4)
    x = jnp.asarray(rng.normal(size=(5, 96)).astype(np.float32))
    y_serial = csb_matvec_sharded(s, x, mesh=mesh, overlap=1)
    for ov in (2, 3):
        y = csb_matvec_sharded(s, x, mesh=mesh, overlap=ov)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(y_serial))
    np.testing.assert_allclose(np.asarray(y_serial),
                               np.asarray(csb_matvec(p, x)),
                               rtol=1e-5, atol=1e-5)


@needs8
def test_refreeze_invalidates_shard_cache(rng):
    """A re-frozen CSBLinear must not serve shards of its old weights."""
    import dataclasses

    from repro.core import CSBLinear
    from repro.dist import Rules, use_rules

    spec = CSBSpec(bm=16, bn=16, prune_rate=0.5)
    w1 = jnp.asarray(rng.normal(size=(96, 64)).astype(np.float32))
    w2 = jnp.asarray(rng.normal(size=(96, 64)).astype(np.float32))
    lin1 = CSBLinear(weight=w1, spec=spec).freeze()
    x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
    with use_rules(Rules({}, mesh=_mesh18())):
        y1 = lin1(x)
        lin2 = dataclasses.replace(lin1, weight=w2).freeze()
        y2 = lin2(x)
    assert lin2._shards is not lin1._shards
    assert not np.allclose(np.asarray(y1), np.asarray(y2))
    y2_local = lin2(x)                      # outside rules: local kernel
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y2_local),
                               rtol=1e-5, atol=1e-5)


@needs8
def test_sharded_mesh_mismatch_raises(rng):
    from repro.kernels.csb_sharded import csb_matvec_sharded

    p, _ = make_padded(rng, (96, 64), 16, 16, 0.5)
    _, s = partition_padded(p, 4)                 # split for 4, mesh has 8
    x = jnp.ones((2, 64), jnp.float32)
    with pytest.raises(ValueError):
        csb_matvec_sharded(s, x, mesh=_mesh18())


@needs8
def test_csb_linear_routes_through_mesh(rng):
    """CSBLinear in csb mode picks the sharded path exactly when rules
    with a non-trivial model axis are active — same numbers either way;
    layers.csb_dense (the model-layer entry) agrees and applies the
    residual tag."""
    from jax.sharding import PartitionSpec as P

    from repro.core import CSBLinear
    from repro.dist import Rules, use_rules
    from repro.models.layers import csb_dense

    w = jnp.asarray(rng.normal(size=(160, 64)).astype(np.float32))
    lin = CSBLinear(weight=w,
                    spec=CSBSpec(bm=16, bn=16, prune_rate=0.5)).freeze()
    x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
    y_local = lin(x)
    rules = Rules({"residual": P("data", None)}, mesh=_mesh18())
    with use_rules(rules):
        y_mesh = lin(x)
        y_layer = csb_dense(x, lin)
    assert (8, "model") in lin._shards            # sharded path was taken
    np.testing.assert_allclose(np.asarray(y_mesh), np.asarray(y_local),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_layer), np.asarray(y_local),
                               rtol=1e-5, atol=1e-5)


@needs8
def test_cell_apply_sharded_weights(rng):
    """cell_apply with ShardedCSB MVM weights == PaddedCSB weights ==
    dense — the paper's RNN serving path, now across devices."""
    from repro.cells import cell_apply, init_params, init_state, make_cell
    from repro.dist import Rules, use_rules

    cell = make_cell("gru", 16, 32)
    params = init_params(cell, jax.random.PRNGKey(2))
    spec = CSBSpec(bm=8, bn=8, prune_rate=0.5)
    csb_params, sharded_params = {}, {}
    for name, w in params.items():
        if w.ndim == 2:
            z = csb_project(w, spec)
            rm, cm = csb_masks(w, spec)
            p = padded_csb_from_dense(
                np.asarray(z), 8, 8,
                row_mask=np.asarray(rm), col_mask=np.asarray(cm))
            csb_params[name] = p
            _, sharded_params[name] = partition_padded(p, 8)
        else:
            csb_params[name] = w
            sharded_params[name] = w
    x = jnp.asarray(rng.normal(size=(2, 16)).astype(np.float32))
    st = init_state(cell, (2,))
    y_csb, _ = cell_apply(cell, csb_params, x, st)
    with use_rules(Rules({}, mesh=_mesh18())):
        y_sh, _ = cell_apply(cell, sharded_params, x, st)
    np.testing.assert_allclose(np.asarray(y_sh), np.asarray(y_csb),
                               rtol=2e-5, atol=2e-5)
    # without an active mesh the sharded weights refuse to run silently
    with pytest.raises(ValueError):
        cell_apply(cell, sharded_params, x, st)


def test_dryrun_partition_report():
    from repro.launch.dryrun import csb_partition_report

    class Cfg:
        d_model = 1024

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 1, "model": 8}

    rep = csb_partition_report(Cfg, FakeMesh())
    assert rep["model_devices"] == 8
    assert rep["greedy"]["imbalance"] <= rep["equal"]["imbalance"]
    assert rep["greedy"]["imbalance"] <= 1.1
    assert sum(rep["greedy"]["device_cycles"]) == \
        sum(rep["equal"]["device_cycles"])
