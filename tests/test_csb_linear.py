"""CSBLinear three-mode equivalence + spec-tree builder."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CSBLinear, CSBSpec, csb_project, csb_specs_for_params
from repro.models import ModelConfig, init_params


def test_modes_agree():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (48, 32))          # (out, in)
    spec = CSBSpec(bm=16, bn=16, prune_rate=0.5)
    x = jax.random.normal(key, (4, 32))

    lin = CSBLinear(weight=w, spec=spec, mode="masked")
    y_masked = lin(x)
    np.testing.assert_allclose(
        np.asarray(y_masked),
        np.asarray(x @ csb_project(w, spec).T), rtol=1e-5, atol=1e-5)

    frozen = lin.freeze()
    y_csb = frozen(x)
    np.testing.assert_allclose(np.asarray(y_csb), np.asarray(y_masked),
                               rtol=2e-5, atol=2e-5)
    assert frozen.compression() > 1.5


def test_transposed_weight():
    key = jax.random.PRNGKey(1)
    w_io = jax.random.normal(key, (32, 48))       # (in, out)
    spec = CSBSpec(bm=16, bn=16, prune_rate=0.5)
    x = jax.random.normal(key, (3, 32))
    lin = CSBLinear(weight=w_io, spec=spec, mode="masked", transposed=True)
    y = lin(x)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(x @ csb_project(w_io.T, spec).T),
        rtol=1e-5, atol=1e-5)


def test_spec_tree_selects_projections():
    cfg = ModelConfig(name="t", mixer="attn", ffn="swiglu", n_layers=2,
                      d_model=64, n_heads=4, n_kv=2, head_dim=16, d_ff=128,
                      vocab=100, dtype="float32")
    params = init_params(jax.random.PRNGKey(2), cfg)
    # min_dim=32 so the small kv projections (2 kv heads x 16 = 32) qualify
    specs = csb_specs_for_params(params, CSBSpec(8, 8, 0.5), min_dim=32)
    flat = jax.tree_util.tree_leaves_with_path(
        specs, is_leaf=lambda x: x is None or isinstance(x, CSBSpec))
    chosen = {tuple(getattr(k, "key", str(k)) for k in path)
              for path, v in flat if isinstance(v, CSBSpec)}
    names = {p[-1] for p in chosen}
    assert {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"} <= names
    # embed/head excluded
    assert not any("embed" in p or "head" in p for p in names)
