"""Deterministic stand-in for ``hypothesis`` (the container may lack it).

Implements just the surface the CSB + paging property tests use —
``given`` with keyword strategies, ``settings``, ``strategies.floats``
/ ``strategies.integers`` / ``strategies.sampled_from`` — by
enumerating a small fixed sample grid instead of random search.
Property coverage degrades gracefully rather than the whole module
failing at collection.
"""
from __future__ import annotations



_N_EXAMPLES = 8


class _Strategy:
    def __init__(self, pick):
        self._pick = pick

    def pick(self, i: int):
        return self._pick(i)


class strategies:  # noqa: N801 — mirrors the hypothesis module name
    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        span = max_value - min_value
        # low-discrepancy sweep across the interval, endpoints included
        return _Strategy(lambda i: min_value + span
                         * ((i * 0.381966 + 0.051) % 1.0
                            if i > 1 else float(i)))

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        span = max_value - min_value
        # endpoints first, then a low-discrepancy interior sweep
        return _Strategy(lambda i: min_value + (
            span if i == 1 else 0 if i == 0
            else int(span * ((i * 0.381966 + 0.051) % 1.0))))

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        seq = list(seq)
        return _Strategy(lambda i: seq[i % len(seq)])


def settings(**_kwargs):
    def deco(fn):
        return fn
    return deco


def given(**strats):
    def deco(fn):
        # NB: no functools.wraps — the runner must present a zero-arg
        # signature or pytest treats the strategy kwargs as fixtures.
        def runner():
            for i in range(_N_EXAMPLES):
                picked = {k: s.pick(i) for k, s in strats.items()}
                fn(**picked)
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        return runner
    return deco
