import os

import numpy as np
import pytest

# NOTE: deliberately no XLA_FLAGS here — tests must see the real (single)
# device; only launch/dryrun.py forces 512 host devices. CI covers the
# sharding paths by exporting XLA_FLAGS=--xla_force_host_platform_
# device_count=8 itself; tests needing multiple devices skip without it.


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)


@pytest.fixture(scope="session", autouse=True)
def _tpu_interpret_golden():
    """CI golden lane: REPRO_FORCE_TPU_INTERPRET=1 runs every Pallas
    call through pltpu.force_tpu_interpret_mode, so the compiled-path
    branch of kernels.csb_mvm.default_interpret (interpret=False, the
    TPU route) is exercised on CPU runners. On a jax without the
    context manager this degrades to the plain interpret path (see
    default_interpret)."""
    if os.environ.get("REPRO_FORCE_TPU_INTERPRET", "0") in ("", "0"):
        yield
        return
    try:
        from jax.experimental.pallas import tpu as pltpu
        cm = pltpu.force_tpu_interpret_mode()
    except (ImportError, AttributeError):
        yield
        return
    with cm:
        yield
