import numpy as np
import pytest

# NOTE: deliberately no XLA_FLAGS here — tests must see the real (single)
# device; only launch/dryrun.py forces 512 host devices.


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)
