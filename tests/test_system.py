"""End-to-end behaviour of the full CSB-RNN stack:

train a small RNN on a synthetic task -> progressively ADMM-CSB prune it
losslessly -> encode to the CSB format -> serve with the Pallas kernel ->
outputs match the masked-dense model; engine simulation reports the
utilization gain of workload sharing on the *same* pruned weights.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cells import cell_apply, init_params, init_state, make_cell, rnn_scan
from repro.core import (
    CSBMatrix, CSBSpec, admm_finalize, admm_init, admm_penalty, admm_update,
    csb_masks, csb_project, density, padded_csb_from_dense,
)
from repro.data import SeqClassifyTask
from repro.engine.simulator import EngineConfig, simulate_matrix


def _train_classifier(steps=60, prune_specs=None, seed=0):
    """Tiny GRU classifier on the synthetic sentiment stand-in."""
    task = SeqClassifyTask(vocab=16, n_classes=4, seq_len=12, seed=seed)
    cell = make_cell("gru", 16, 32)
    key = jax.random.PRNGKey(seed)
    params = init_params(cell, key)
    params["emb"] = jax.random.normal(key, (16, 16)) * 0.3
    params["out"] = jax.random.normal(key, (32, 4)) * 0.3

    def loss_fn(p, toks, labels, admm_state=None):
        xs = p["emb"][toks].transpose(1, 0, 2)   # (T, B, 16)
        ys, _ = rnn_scan(cell, {k: v for k, v in p.items()
                                if k not in ("emb", "out")}, xs)
        logits = ys[-1] @ p["out"]
        ll = jax.nn.log_softmax(logits)
        loss = -jnp.mean(jnp.take_along_axis(ll, labels[:, None], 1))
        if admm_state is not None:
            loss = loss + admm_penalty(p, admm_state, prune_specs)
        return loss

    admm_state = (admm_init(params, prune_specs, rho=0.02)
                  if prune_specs else None)
    lr = 0.05
    grad = jax.grad(loss_fn)
    for step in range(steps):
        b = task.batch(step, 32)
        g = grad(params, jnp.asarray(b["tokens"]), jnp.asarray(b["labels"]),
                 admm_state)
        params = jax.tree.map(lambda w, gg: w - lr * gg, params, g)
        if prune_specs and (step + 1) % 10 == 0:
            admm_state = admm_update(params, admm_state, prune_specs)
    if prune_specs:
        params = admm_finalize(params, prune_specs)

    def accuracy(p):
        correct = total = 0
        for step in range(100, 104):
            b = task.batch(step, 64)
            xs = p["emb"][jnp.asarray(b["tokens"])].transpose(1, 0, 2)
            ys, _ = rnn_scan(cell, {k: v for k, v in p.items()
                                    if k not in ("emb", "out")}, xs)
            pred = jnp.argmax(ys[-1] @ p["out"], -1)
            correct += int((pred == jnp.asarray(b["labels"])).sum())
            total += 64
        return correct / total

    return cell, params, accuracy


def test_end_to_end_csb_pipeline():
    # 1. dense baseline (150 steps: at 60 this jax version's RNG leaves
    # the GRU under-trained at ~0.47 — threshold unchanged)
    cell, dense_params, acc_fn = _train_classifier(steps=150)
    dense_acc = acc_fn(dense_params)
    assert dense_acc > 0.5, dense_acc

    # 2. ADMM-CSB prune the recurrent matrices at 50%
    spec = CSBSpec(bm=8, bn=8, prune_rate=0.5)
    specs = jax.tree.map(lambda _: None, dense_params)
    for name in ("U_z", "U_r", "U_n"):
        specs[name] = spec
    cell2, pruned_params, acc_fn2 = _train_classifier(prune_specs=specs,
                                                      steps=100)
    pruned_acc = acc_fn2(pruned_params)
    assert pruned_acc > max(dense_acc - 0.2, 0.4), (dense_acc, pruned_acc)
    assert float(density(pruned_params["U_z"])) <= 0.56

    # 3. encode to CSB + serve via the Pallas kernel — same outputs
    serve_dense = {k: v for k, v in pruned_params.items()
                   if k not in ("emb", "out")}
    serve_csb = dict(serve_dense)
    for name in ("U_z", "U_r", "U_n"):
        w = pruned_params[name]
        rm, cm = csb_masks(w, spec)
        serve_csb[name] = padded_csb_from_dense(
            np.asarray(w), 8, 8, row_mask=np.asarray(rm),
            col_mask=np.asarray(cm))
    x = jax.random.normal(jax.random.PRNGKey(9), (4, 16))
    st = init_state(cell, (4,))
    y_a, _ = cell_apply(cell, serve_dense, x, st)
    y_b, _ = cell_apply(cell, serve_csb, x, st)
    np.testing.assert_allclose(np.asarray(y_b), np.asarray(y_a),
                               rtol=3e-5, atol=3e-5)

    # 4. engine: sharing improves utilization on these exact weights
    w = pruned_params["U_n"]
    rm, cm = csb_masks(w, spec)
    csb = CSBMatrix.from_dense(np.asarray(w), 8, 8, np.asarray(rm),
                               np.asarray(cm))
    e = EngineConfig(K=2, L=2, P=4, Q=4)
    eff0 = simulate_matrix(csb, e, "none").efficiency
    eff2 = simulate_matrix(csb, e, "2d").efficiency
    assert eff2 >= eff0
