"""int8 error-feedback gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.compress import (
    compress, compress_init, compression_ratio, decompress,
)


def test_roundtrip_error_bounded():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64))}
    res = compress_init(g)
    comp, res = compress(g, res)
    back = decompress(comp)
    err = float(jnp.max(jnp.abs(back["w"] - g["w"])))
    scale = float(comp["w"].scale)
    assert err <= scale * 0.51  # half-ULP of the int8 grid


def test_error_feedback_is_unbiased_over_steps():
    """Sum of decompressed grads ~ sum of true grads (error feedback)."""
    key = jax.random.PRNGKey(1)
    res = compress_init({"w": jnp.zeros((32,))})
    total_true = jnp.zeros((32,))
    total_sent = jnp.zeros((32,))
    for i in range(50):
        key, k = jax.random.split(key)
        g = {"w": jax.random.normal(k, (32,)) * 0.01}
        comp, res = compress(g, res)
        total_true += g["w"]
        total_sent += decompress(comp)["w"]
    # residual carries what wasn't sent: totals match within last residual
    np.testing.assert_allclose(np.asarray(total_sent + res["w"]),
                               np.asarray(total_true), rtol=1e-4, atol=1e-5)


def test_compression_ratio():
    g = {"w": jnp.zeros((1024, 1024), jnp.float32)}
    assert 3.9 < compression_ratio(g) <= 4.0


def test_training_with_compression_converges():
    """SGD on a quadratic with compressed grads still converges."""
    params = {"w": jnp.asarray([4.0, -3.0, 2.0])}
    res = compress_init(params)
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        comp, res = compress(g, res)
        g = decompress(comp)
        params = jax.tree.map(lambda w, gg: w - 0.05 * gg, params, g)
    assert float(jnp.sum(params["w"] ** 2)) < 1e-3
