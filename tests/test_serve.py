"""Serving paths: batched generate + frame-by-frame RNN serving."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.cells import init_params, make_cell
from repro.core import CSBSpec, csb_masks, csb_project, padded_csb_from_dense
from repro.models import ModelConfig, init_params as lm_init
from repro.serve import EngineConfig, generate, rnn_serve_frames

CFG = ModelConfig(name="tiny", mixer="attn", ffn="swiglu", n_layers=2,
                  d_model=32, n_heads=2, n_kv=2, head_dim=16, d_ff=64,
                  vocab=50, dtype="float32", logit_chunk=16, remat=False)


def test_generate_greedy_deterministic():
    params = lm_init(jax.random.PRNGKey(0), CFG)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 50)
    out1 = generate(params, CFG, prompt, EngineConfig(max_new_tokens=6))
    out2 = generate(params, CFG, prompt, EngineConfig(max_new_tokens=6))
    assert out1.shape == (2, 14)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert int(out1.max()) < 50


def test_generate_matches_teacher_forcing():
    """Greedy generation must agree with running prefill on the grown
    sequence at every step (cache correctness through the serve loop)."""
    from repro.models import prefill
    params = lm_init(jax.random.PRNGKey(3), CFG)
    prompt = jax.random.randint(jax.random.PRNGKey(4), (1, 6), 0, 50)
    out = np.asarray(generate(params, CFG, prompt,
                              EngineConfig(max_new_tokens=4)))
    seq = prompt
    for i in range(4):
        logits, _ = prefill(params, {"tokens": jnp.asarray(seq)}, CFG)
        nxt = int(jnp.argmax(logits[0]))
        assert nxt == out[0, 6 + i], (i, nxt, out)
        seq = np.concatenate([np.asarray(seq), [[nxt]]], axis=1)


def test_rnn_serve_frames_csb():
    cell = make_cell("lstm", 16, 32)
    params = init_params(cell, jax.random.PRNGKey(5))
    spec = CSBSpec(bm=8, bn=8, prune_rate=0.5)
    csb_params = {}
    for k, w in params.items():
        if w.ndim == 2:
            z = csb_project(w, spec)
            rm, cm = csb_masks(w, spec)
            csb_params[k] = padded_csb_from_dense(
                np.asarray(z), 8, 8, row_mask=np.asarray(rm),
                col_mask=np.asarray(cm))
        else:
            csb_params[k] = w
    frames = jax.random.normal(jax.random.PRNGKey(6), (5, 2, 16))
    outs, st, us = rnn_serve_frames(cell, csb_params, frames, warmup=1)
    assert outs.shape == (5, 2, 32)
    assert np.isfinite(np.asarray(outs)).all()
    assert us > 0
